//! Architectural state: per-tile MCG + DC state and the shared VOP
//! datapath (Fig. 7), plus the functional semantics of each stage
//! operation.

use super::config::AccelConfig;
use crate::vsa::ca90;

/// Per-tile state: MCG (SRAM, CA-90 RF, QRY) and DC (DSUM RF, ARGMAX).
#[derive(Debug, Clone)]
pub struct Tile {
    /// Local SRAM as fold slots (each `fold_words` u64s).
    pub sram: Vec<u64>,
    /// CA-90 register file: `R` fold-sized entries.
    pub ca90_rf: Vec<Vec<u64>>,
    /// Query register (one fold).
    pub qry: Vec<u64>,
    /// DSUM RF: `D` distance accumulators.
    pub dsum_rf: Vec<i64>,
    /// Last-latched distance (feeds resonator weighting).
    pub dsum_latch: i64,
    /// ARGMAX running best (score, item id).
    pub best: (i64, u32),
    /// Per-tile binary datapath latch (one fold).
    pub datapath: Vec<u64>,
    fold_words: usize,
    sram_folds: usize,
}

impl Tile {
    pub fn new(cfg: &AccelConfig) -> Self {
        let fw = cfg.fold_words();
        Tile {
            sram: vec![0u64; cfg.sram_folds_per_tile() * fw],
            ca90_rf: vec![vec![0u64; fw]; cfg.ca90_rf],
            qry: vec![0u64; fw],
            dsum_rf: vec![0i64; cfg.dsum_rf],
            dsum_latch: 0,
            best: (i64::MIN, u32::MAX),
            datapath: vec![0u64; fw],
            fold_words: fw,
            sram_folds: cfg.sram_folds_per_tile(),
        }
    }

    /// Fold capacity of this tile's SRAM.
    pub fn sram_folds(&self) -> usize {
        self.sram_folds
    }

    /// Read fold slot `addr` from SRAM.
    pub fn sram_fold(&self, addr: usize) -> &[u64] {
        assert!(addr < self.sram_folds, "SRAM fold address {addr} out of range");
        &self.sram[addr * self.fold_words..(addr + 1) * self.fold_words]
    }

    /// Write fold slot `addr`.
    pub fn write_sram_fold(&mut self, addr: usize, fold: &[u64]) {
        assert!(addr < self.sram_folds, "SRAM fold address {addr} out of range");
        assert_eq!(fold.len(), self.fold_words);
        self.sram[addr * self.fold_words..(addr + 1) * self.fold_words]
            .copy_from_slice(fold);
    }

    /// One CA-90 generation on RF entry `rf`, result written back and
    /// placed on the datapath.
    pub fn ca90_generate(&mut self, rf: usize, bus_bits: usize) {
        let next = ca90::ca90_step(&self.ca90_rf[rf], bus_bits);
        self.ca90_rf[rf] = next.clone();
        self.datapath = next;
    }

    /// Reset DC search state.
    pub fn reset_search(&mut self) {
        self.best = (i64::MIN, u32::MAX);
        for d in &mut self.dsum_rf {
            *d = 0;
        }
    }
}

/// Shared VOP subsystem state (Fig. 7): one instance per accelerator.
#[derive(Debug, Clone)]
pub struct VopState {
    /// Bind buffer (binary fold latch feeding the XOR array).
    pub bind_buf: Vec<u64>,
    /// Integer datapath lanes (bus_width lanes).
    pub int_lanes: Vec<i32>,
    /// BND RF: `B` integer accumulators, each bus_width lanes.
    pub bnd_rf: Vec<Vec<i64>>,
    /// SGN result register (binary fold).
    pub result: Vec<u64>,
    bus_width: usize,
}

impl VopState {
    pub fn new(cfg: &AccelConfig) -> Self {
        let fw = cfg.fold_words();
        VopState {
            bind_buf: vec![0u64; fw],
            int_lanes: vec![0i32; cfg.bus_width],
            bnd_rf: vec![vec![0i64; cfg.bus_width]; cfg.bnd_rf],
            result: vec![0u64; fw],
            bus_width: cfg.bus_width,
        }
    }

    /// Binary fold → bipolar integer lanes (bit 1 → +1, bit 0 → -1): the
    /// MULT unit's format conversion.
    pub fn b2i(&mut self, fold: &[u64]) {
        for lane in 0..self.bus_width {
            let bit = (fold[lane / 64] >> (lane % 64)) & 1;
            self.int_lanes[lane] = if bit == 1 { 1 } else { -1 };
        }
    }

    /// Scale integer lanes by `w`.
    pub fn scale(&mut self, w: i64) {
        for lane in &mut self.int_lanes {
            *lane = (*lane as i64 * w).clamp(i32::MIN as i64, i32::MAX as i64) as i32;
        }
    }

    /// Accumulate lanes into BND RF entry `rf2` (optionally resetting).
    pub fn accum(&mut self, rf2: usize, reset: bool) {
        let acc = &mut self.bnd_rf[rf2];
        if reset {
            for a in acc.iter_mut() {
                *a = 0;
            }
        }
        for (a, l) in acc.iter_mut().zip(&self.int_lanes) {
            *a += *l as i64;
        }
    }

    /// Fused MULT→BND path: convert, scale and accumulate in a single
    /// pass over the lanes (the pipeline's per-word hot loop; see
    /// EXPERIMENTS.md §Perf). Architecturally identical to
    /// `b2i`+`scale`+`accum` — `int_lanes` is still updated.
    pub fn fused_scale_accum(&mut self, fold: &[u64], w: i64, rf2: usize, reset: bool) {
        let acc = &mut self.bnd_rf[rf2];
        if reset {
            acc.iter_mut().for_each(|a| *a = 0);
        }
        debug_assert_eq!(self.bus_width % 64, 0);
        let wi = w.clamp(i32::MIN as i64, i32::MAX as i64) as i32;
        for (wi_idx, &word) in fold.iter().enumerate() {
            let base = wi_idx * 64;
            let lanes = &mut self.int_lanes[base..base + 64];
            let accs = &mut acc[base..base + 64];
            for b in 0..64 {
                let v = if (word >> b) & 1 == 1 { wi } else { -wi };
                lanes[b] = v;
                accs[b] += v as i64;
            }
        }
    }

    /// Bipolarize BND RF entry `rf2` into the result register (≥0 → 1).
    pub fn sign(&mut self, rf2: usize) {
        let fw = self.result.len();
        for w in &mut self.result {
            *w = 0;
        }
        for lane in 0..self.bus_width {
            if self.bnd_rf[rf2][lane] >= 0 {
                self.result[lane / 64] |= 1u64 << (lane % 64);
            }
        }
        let _ = fw;
    }
}

/// POPCNT distance partial: bipolar dot of two folds = W - 2*hamming.
pub fn popcnt_partial(a: &[u64], b: &[u64], bus_width: usize) -> i64 {
    let ham: u32 = a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum();
    bus_width as i64 - 2 * ham as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> AccelConfig {
        AccelConfig::acc4()
    }

    #[test]
    fn sram_roundtrip() {
        let mut t = Tile::new(&cfg());
        let fold: Vec<u64> = (0..8).map(|i| i as u64 * 7 + 1).collect();
        t.write_sram_fold(37, &fold);
        assert_eq!(t.sram_fold(37), &fold[..]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn sram_bounds_checked() {
        let t = Tile::new(&cfg());
        t.sram_fold(100_000);
    }

    #[test]
    fn ca90_generate_writes_back() {
        let mut t = Tile::new(&cfg());
        let mut rng = Rng::new(1);
        t.ca90_rf[1] = (0..8).map(|_| rng.next_u64()).collect();
        let before = t.ca90_rf[1].clone();
        t.ca90_generate(1, 512);
        assert_ne!(t.ca90_rf[1], before);
        assert_eq!(t.datapath, t.ca90_rf[1]);
        let expect = crate::vsa::ca90::ca90_step(&before, 512);
        assert_eq!(t.ca90_rf[1], expect);
    }

    #[test]
    fn b2i_maps_bits_to_bipolar() {
        let mut v = VopState::new(&cfg());
        let mut fold = vec![0u64; 8];
        fold[0] = 0b101;
        v.b2i(&fold);
        assert_eq!(v.int_lanes[0], 1);
        assert_eq!(v.int_lanes[1], -1);
        assert_eq!(v.int_lanes[2], 1);
        assert_eq!(v.int_lanes[3], -1);
    }

    #[test]
    fn accum_and_sign_roundtrip() {
        let mut v = VopState::new(&cfg());
        let mut fold = vec![u64::MAX; 8];
        fold[0] = !1u64; // lane 0 = 0 → -1
        v.b2i(&fold);
        v.accum(0, true);
        v.accum(0, false); // lane 0 = -2, others +2
        v.sign(0);
        assert_eq!(v.result[0] & 1, 0, "negative lane bipolarizes to 0");
        assert_eq!(v.result[0] >> 1, u64::MAX >> 1);
    }

    #[test]
    fn popcnt_partial_is_bipolar_dot() {
        let a = vec![u64::MAX; 8];
        let b = vec![0u64; 8];
        assert_eq!(popcnt_partial(&a, &a, 512), 512);
        assert_eq!(popcnt_partial(&a, &b, 512), -512);
    }

    #[test]
    fn scale_by_negative_weight() {
        let mut v = VopState::new(&cfg());
        v.b2i(&vec![u64::MAX; 8]);
        v.scale(-3);
        assert!(v.int_lanes.iter().all(|&l| l == -3));
    }
}
