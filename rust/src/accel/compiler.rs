//! Kernel compiler: lowers the paper's VSA kernel calculus (Sec. VI-B —
//! sub-functions `a`/`b` encoding, `c` projection, `d` similarity, `e`
//! argmax) into Instruction-Word programs (Fig. 6's programming method).
//!
//! Operand placement follows [`super::pipeline::Layout`]: codebook items
//! are striped across tiles, scratch vectors are broadcast to every tile.
//! Shared-VOP words target exactly one tile; MCG/DC words broadcast SIMD
//! across the tile mask.  Results always return to memory through the
//! SGN → global-datapath path, exactly as the paper describes fold
//! transfer ("converted to binary through SGN for transfer over the
//! global vector-symbolic datapath").

use super::config::AccelConfig;
use super::isa::{
    BindOp, BndOp, DcOp, InstructionWord, MemOp, MultOp, OpParam, QryOp, SgnOp,
};
use super::pipeline::Layout;
use super::program::Program;

/// A vector operand location.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VecRef {
    /// Codebook item by global id (resident on `layout.tile_of(id)`).
    Item(usize),
    /// Scratch slot (broadcast-resident on every tile).
    Scratch(usize),
}

/// One encoding operand: a vector reference with an optional positional
/// permutation (rho^shift) applied on load — the paper's
/// `b(y, (s2=3))` sequence-preserving binding.
#[derive(Debug, Clone, Copy)]
pub struct Operand {
    pub vec: VecRef,
    pub shift: i32,
}

impl Operand {
    pub fn plain(vec: VecRef) -> Self {
        Operand { vec, shift: 0 }
    }

    pub fn permuted(vec: VecRef, shift: i32) -> Self {
        Operand { vec, shift }
    }
}

/// Compiles kernel-calculus operations into instruction-word programs for
/// a fixed configuration + data layout.
#[derive(Debug, Clone)]
pub struct KernelCompiler {
    pub cfg: AccelConfig,
    pub layout: Layout,
}

impl KernelCompiler {
    pub fn new(cfg: AccelConfig, layout: Layout) -> Self {
        KernelCompiler { cfg, layout }
    }

    fn fpv(&self) -> usize {
        self.layout.folds_per_vec
    }

    /// (tile, fold address) of operand's fold `f`. Scratch may resolve on
    /// any tile; `prefer` picks one (keeps VOP chains on a single tile
    /// when possible).
    fn resolve(&self, v: VecRef, f: usize, prefer: usize) -> (usize, usize) {
        match v {
            VecRef::Item(g) => {
                assert!(g < self.layout.n_items, "item {g} out of range");
                (
                    self.layout.tile_of(g),
                    self.layout.local_addr(self.layout.local_of(g)) + f,
                )
            }
            VecRef::Scratch(slot) => (prefer, self.layout.scratch_addr(slot) + f),
        }
    }

    /// Tile mask for tiles that hold local item index `local`.
    fn mask_for_local(&self, local: usize) -> u64 {
        let mut m = 0u64;
        for t in 0..self.layout.n_tiles {
            if self.layout.items_on_tile(t) > local {
                m |= 1 << t;
            }
        }
        m
    }

    fn all_mask(&self) -> u64 {
        (1u64 << self.layout.n_tiles) - 1
    }

    /// Emit one fold of a bind chain: XOR of `ops` (with per-operand
    /// permutes), ending with the bound fold routed through
    /// MULT→BND→SGN into the shared result register, then broadcast-stored
    /// to scratch `dst`. Appends to `p`.
    fn emit_bind_fold(&self, p: &mut Program, ops: &[Operand], f: usize, dst: usize) {
        assert!(!ops.is_empty());
        let (t0, a0) = self.resolve(ops[0].vec, f, 0);
        if ops.len() == 1 {
            // Single operand: pass through MULT/BND to reach SGN.
            p.push(InstructionWord {
                mem: MemOp::LoadSram,
                qry: if ops[0].shift != 0 {
                    QryOp::Permute
                } else {
                    QryOp::Nop
                },
                mult: MultOp::B2I,
                bnd: BndOp::ResetAccum,
                param: OpParam {
                    addr: a0,
                    shift: ops[0].shift,
                    rf2: 0,
                    tile_mask: 1 << t0,
                    ..Default::default()
                },
                ..Default::default()
            });
        } else {
            p.push(InstructionWord {
                mem: MemOp::LoadSram,
                qry: if ops[0].shift != 0 {
                    QryOp::Permute
                } else {
                    QryOp::Nop
                },
                bind: BindOp::SetBuf,
                param: OpParam {
                    addr: a0,
                    shift: ops[0].shift,
                    tile_mask: 1 << t0,
                    ..Default::default()
                },
                ..Default::default()
            });
            for (i, op) in ops.iter().enumerate().skip(1) {
                let last = i == ops.len() - 1;
                let (t, a) = self.resolve(op.vec, f, t0);
                p.push(InstructionWord {
                    mem: MemOp::LoadSram,
                    qry: if op.shift != 0 {
                        QryOp::Permute
                    } else {
                        QryOp::Nop
                    },
                    bind: BindOp::Xor,
                    mult: if last { MultOp::B2I } else { MultOp::Nop },
                    bnd: if last { BndOp::ResetAccum } else { BndOp::Nop },
                    param: OpParam {
                        addr: a,
                        shift: op.shift,
                        rf2: 0,
                        tile_mask: 1 << t,
                        ..Default::default()
                    },
                    ..Default::default()
                });
                if !last {
                    // Latch the partial XOR back into the bind buffer.
                    p.push(InstructionWord {
                        bind: BindOp::SetBuf,
                        param: OpParam {
                            tile_mask: 1 << t,
                            ..Default::default()
                        },
                        ..Default::default()
                    });
                }
            }
        }
        // SGN broadcast: result register → every tile's scratch.
        p.push(InstructionWord {
            sgn: SgnOp::Sign,
            param: OpParam {
                rf2: 0,
                tile_mask: 1, // shared unit; single-tile issue
                ..Default::default()
            },
            ..Default::default()
        });
        p.push(InstructionWord {
            mem: MemOp::StoreResult,
            param: OpParam {
                addr: self.layout.scratch_addr(dst) + f,
                tile_mask: self.all_mask(),
                ..Default::default()
            },
            ..Default::default()
        });
    }

    /// Bind `ops` into scratch `dst`: paper's `b(y, s2)` kernel
    /// (plain XOR chain; with shifts, the positional variant).
    pub fn bind(&self, ops: &[Operand], dst: usize) -> Program {
        let mut p = Program::new(format!("bind{}→s{}", ops.len(), dst));
        for f in 0..self.fpv() {
            self.emit_bind_fold(&mut p, ops, f, dst);
        }
        p
    }

    /// Weighted bundle-of-bind-chains into scratch `dst`: the paper's
    /// `a(y, (1, s2))` encoding kernel with MULT weighting:
    /// `dst = sign( Σ_g w_g · bind(ops_g) )`.
    ///
    /// Folds are processed in chunks of the `B` BND accumulators; each
    /// chunk streams every group once (BND RF capacity is why MULT-style
    /// encoding barely benefits from larger accelerator instances).
    pub fn weighted_bundle(&self, groups: &[(Vec<Operand>, i32)], dst: usize) -> Program {
        let mut p = Program::new(format!("wbundle{}→s{}", groups.len(), dst));
        let b = self.cfg.bnd_rf;
        let fpv = self.fpv();
        let mut chunk_start = 0;
        while chunk_start < fpv {
            let chunk_end = (chunk_start + b).min(fpv);
            for (gi, (ops, w)) in groups.iter().enumerate() {
                for f in chunk_start..chunk_end {
                    self.emit_weighted_group_fold(
                        &mut p,
                        ops,
                        *w,
                        f,
                        f - chunk_start,
                        gi == 0,
                    );
                }
            }
            for f in chunk_start..chunk_end {
                p.push(InstructionWord {
                    sgn: SgnOp::Sign,
                    param: OpParam {
                        rf2: f - chunk_start,
                        tile_mask: 1,
                        ..Default::default()
                    },
                    ..Default::default()
                });
                p.push(InstructionWord {
                    mem: MemOp::StoreResult,
                    param: OpParam {
                        addr: self.layout.scratch_addr(dst) + f,
                        tile_mask: self.all_mask(),
                        ..Default::default()
                    },
                    ..Default::default()
                });
            }
            chunk_start = chunk_end;
        }
        p
    }

    /// One fold of one weighted group: bind chain (if >1 operand) with the
    /// final word carrying MULT Scale(w) + BND accumulate into `rf2`.
    fn emit_weighted_group_fold(
        &self,
        p: &mut Program,
        ops: &[Operand],
        w: i32,
        f: usize,
        rf2: usize,
        reset: bool,
    ) {
        let bnd = if reset { BndOp::ResetAccum } else { BndOp::Accum };
        let (t0, a0) = self.resolve(ops[0].vec, f, 0);
        if ops.len() == 1 {
            p.push(InstructionWord {
                mem: MemOp::LoadSram,
                qry: if ops[0].shift != 0 {
                    QryOp::Permute
                } else {
                    QryOp::Nop
                },
                mult: MultOp::Scale,
                bnd,
                param: OpParam {
                    addr: a0,
                    shift: ops[0].shift,
                    weight: w,
                    rf2,
                    tile_mask: 1 << t0,
                    ..Default::default()
                },
                ..Default::default()
            });
            return;
        }
        p.push(InstructionWord {
            mem: MemOp::LoadSram,
            qry: if ops[0].shift != 0 {
                QryOp::Permute
            } else {
                QryOp::Nop
            },
            bind: BindOp::SetBuf,
            param: OpParam {
                addr: a0,
                shift: ops[0].shift,
                tile_mask: 1 << t0,
                ..Default::default()
            },
            ..Default::default()
        });
        for (i, op) in ops.iter().enumerate().skip(1) {
            let last = i == ops.len() - 1;
            let (t, a) = self.resolve(op.vec, f, t0);
            p.push(InstructionWord {
                mem: MemOp::LoadSram,
                qry: if op.shift != 0 {
                    QryOp::Permute
                } else {
                    QryOp::Nop
                },
                bind: BindOp::Xor,
                mult: if last { MultOp::Scale } else { MultOp::Nop },
                bnd: if last { bnd } else { BndOp::Nop },
                param: OpParam {
                    addr: a,
                    shift: op.shift,
                    weight: w,
                    rf2,
                    tile_mask: 1 << t,
                    ..Default::default()
                },
                ..Default::default()
            });
            if !last {
                p.push(InstructionWord {
                    bind: BindOp::SetBuf,
                    param: OpParam {
                        tile_mask: 1 << t,
                        ..Default::default()
                    },
                    ..Default::default()
                });
            }
        }
    }

    /// Nearest-neighbor search of scratch `query` against all `n_items`
    /// codebook items: the paper's `e(y) = argmax_i d(y_i, ȳ)` kernel.
    ///
    /// Items are searched SIMD across tiles in groups of the `D` DSUM
    /// registers; the query fold is latched into QRY once per (group,
    /// fold), which is why more DSUM registers (and more tiles) speed up
    /// search-heavy workloads like REACT (Fig. 11a).
    ///
    /// Run [`super::pipeline::Accelerator::reset_search`] first and read
    /// the winner with `global_best`.
    pub fn search(&self, query: usize, n_items: usize) -> Program {
        assert!(n_items <= self.layout.n_items);
        let mut p = Program::new(format!("search s{query} over {n_items}"));
        let d_regs = self.cfg.dsum_rf;
        let fpv = self.fpv();
        // local index range covering n_items across tiles
        let max_local = (n_items + self.layout.n_tiles - 1) / self.layout.n_tiles;
        let mut g0 = 0;
        while g0 < max_local {
            let g1 = (g0 + d_regs).min(max_local);
            for f in 0..fpv {
                p.push(InstructionWord {
                    mem: MemOp::LoadSram,
                    qry: QryOp::SetQry,
                    param: OpParam {
                        addr: self.layout.scratch_addr(query) + f,
                        tile_mask: self.all_mask(),
                        ..Default::default()
                    },
                    ..Default::default()
                });
                for local in g0..g1 {
                    let mask = self.mask_for_local(local) & self.items_mask(local, n_items);
                    if mask == 0 {
                        continue;
                    }
                    p.push(InstructionWord {
                        mem: MemOp::LoadSram,
                        sgn: SgnOp::Popcnt,
                        dc: if f == 0 { DcOp::DsumReset } else { DcOp::DsumAcc },
                        param: OpParam {
                            addr: self.layout.local_addr(local) + f,
                            dsum: local - g0,
                            item: local as u32,
                            tile_mask: mask,
                            ..Default::default()
                        },
                        ..Default::default()
                    });
                }
            }
            for local in g0..g1 {
                let mask = self.mask_for_local(local) & self.items_mask(local, n_items);
                if mask == 0 {
                    continue;
                }
                p.push(InstructionWord {
                    dc: DcOp::ArgmaxUpdate,
                    param: OpParam {
                        dsum: local - g0,
                        item: local as u32,
                        tile_mask: mask,
                        ..Default::default()
                    },
                    ..Default::default()
                });
            }
            g0 = g1;
        }
        p
    }

    /// Tiles whose item at `local` has a global id < `n_items`.
    fn items_mask(&self, local: usize, n_items: usize) -> u64 {
        let mut m = 0u64;
        for t in 0..self.layout.n_tiles {
            if self.layout.global_id(t, local) < n_items {
                m |= 1 << t;
            }
        }
        m
    }

    /// Resonator projection for one factor: the paper's
    /// `c(y) = Σ_i n_i · y_i` with `n_i = d(a_i, x̂)` computed in DC and
    /// fed back through `MULT` (ScaleByDsum):
    /// `dst = sign( Σ_{g ∈ factor} d(item_g, x̂) · item_g )`.
    ///
    /// Folds chunk by the `B` BND accumulators; each pass re-streams every
    /// item and recomputes its distance (DSUM holds only scalars), so
    /// smaller instances pay ceil(F/B) passes — the source of FACT's
    /// scaling behaviour in Fig. 11a.
    pub fn project(&self, xhat: usize, factor_items: &[usize], dst: usize) -> Program {
        let mut p = Program::new(format!("project s{xhat}→s{dst}"));
        let b = self.cfg.bnd_rf;
        let fpv = self.fpv();
        let mut chunk_start = 0;
        while chunk_start < fpv {
            let chunk_end = (chunk_start + b).min(fpv);
            for (gi, &g) in factor_items.iter().enumerate() {
                let t = self.layout.tile_of(g);
                let base = self.layout.local_addr(self.layout.local_of(g));
                // distance d(item_g, xhat) → dsum[0] on tile t
                for f in 0..fpv {
                    p.push(InstructionWord {
                        mem: MemOp::LoadSram,
                        qry: QryOp::SetQry,
                        param: OpParam {
                            addr: self.layout.scratch_addr(xhat) + f,
                            tile_mask: 1 << t,
                            ..Default::default()
                        },
                        ..Default::default()
                    });
                    p.push(InstructionWord {
                        mem: MemOp::LoadSram,
                        sgn: SgnOp::Popcnt,
                        dc: if f == 0 { DcOp::DsumReset } else { DcOp::DsumAcc },
                        param: OpParam {
                            addr: base + f,
                            dsum: 0,
                            tile_mask: 1 << t,
                            ..Default::default()
                        },
                        ..Default::default()
                    });
                }
                p.push(InstructionWord {
                    dc: DcOp::DsumLatch,
                    param: OpParam {
                        dsum: 0,
                        tile_mask: 1 << t,
                        ..Default::default()
                    },
                    ..Default::default()
                });
                // weighted accumulate of this chunk's folds
                for f in chunk_start..chunk_end {
                    p.push(InstructionWord {
                        mem: MemOp::LoadSram,
                        mult: MultOp::ScaleByDsum,
                        bnd: if gi == 0 { BndOp::ResetAccum } else { BndOp::Accum },
                        param: OpParam {
                            addr: base + f,
                            rf2: f - chunk_start,
                            tile_mask: 1 << t,
                            ..Default::default()
                        },
                        ..Default::default()
                    });
                }
            }
            for f in chunk_start..chunk_end {
                p.push(InstructionWord {
                    sgn: SgnOp::Sign,
                    param: OpParam {
                        rf2: f - chunk_start,
                        tile_mask: 1,
                        ..Default::default()
                    },
                    ..Default::default()
                });
                p.push(InstructionWord {
                    mem: MemOp::StoreResult,
                    param: OpParam {
                        addr: self.layout.scratch_addr(dst) + f,
                        tile_mask: self.all_mask(),
                        ..Default::default()
                    },
                    ..Default::default()
                });
            }
            chunk_start = chunk_end;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::ControlMethod;
    use crate::accel::pipeline::Accelerator;
    use crate::util::Rng;
    use crate::vsa::hypervector::BinaryHV;
    use crate::vsa::BinaryCodebook;

    const DIM: usize = 4096;

    fn setup(n_items: usize) -> (Accelerator, KernelCompiler, BinaryCodebook) {
        let mut acc = Accelerator::new(AccelConfig::acc4());
        let mut rng = Rng::new(123);
        let cb = BinaryCodebook::random(&mut rng, n_items, DIM);
        let layout = acc.load_items(cb.items(), 8);
        let kc = KernelCompiler::new(acc.cfg.clone(), layout);
        (acc, kc, cb)
    }

    #[test]
    fn bind_two_items_matches_functional() {
        let (mut acc, kc, cb) = setup(10);
        let p = kc.bind(
            &[
                Operand::plain(VecRef::Item(3)),
                Operand::plain(VecRef::Item(7)),
            ],
            0,
        );
        acc.run(&p, ControlMethod::Mopc);
        let got = acc.read_scratch(&kc.layout, 0, 0);
        assert_eq!(got, cb.item(3).bind(cb.item(7)));
        // broadcast: every tile holds the result
        for t in 1..acc.cfg.n_tiles {
            assert_eq!(acc.read_scratch(&kc.layout, t, 0), got);
        }
    }

    #[test]
    fn bind_three_items_matches_functional() {
        let (mut acc, kc, cb) = setup(10);
        let p = kc.bind(
            &[
                Operand::plain(VecRef::Item(0)),
                Operand::plain(VecRef::Item(1)),
                Operand::plain(VecRef::Item(2)),
            ],
            1,
        );
        acc.run(&p, ControlMethod::Sopc);
        let expect = cb.item(0).bind(cb.item(1)).bind(cb.item(2));
        assert_eq!(acc.read_scratch(&kc.layout, 2, 1), expect);
    }

    #[test]
    fn positional_bind_uses_fold_local_permute() {
        // Positional binding permutes within each fold (hardware permutes
        // the 512-bit datapath). Functional expectation: per-fold rotate.
        let (mut acc, kc, cb) = setup(6);
        let p = kc.bind(
            &[
                Operand::plain(VecRef::Item(0)),
                Operand::permuted(VecRef::Item(1), 1),
            ],
            0,
        );
        acc.run(&p, ControlMethod::Mopc);
        let got = acc.read_scratch(&kc.layout, 0, 0);
        // expected: fold-wise rotate of item1 then XOR
        let fpv = kc.layout.folds_per_vec;
        let mut words = Vec::new();
        for f in 0..fpv {
            let rot = crate::accel::pipeline::rotate_fold(cb.item(1).fold(f), 512, 1);
            for (a, b) in cb.item(0).fold(f).iter().zip(&rot) {
                words.push(a ^ b);
            }
        }
        assert_eq!(got, BinaryHV::from_words(DIM, words));
    }

    #[test]
    fn search_finds_nearest_neighbor() {
        let (mut acc, kc, cb) = setup(55);
        let mut rng = Rng::new(77);
        // noisy copy of item 23
        let mut q = cb.item(23).clone();
        for i in rng.sample_indices(DIM, DIM / 5) {
            q.set(i, !q.get(i));
        }
        acc.stage_scratch(&kc.layout, 0, &q);
        acc.reset_search();
        let p = kc.search(0, 55);
        acc.run(&p, ControlMethod::Mopc);
        let (gid, score) = acc.global_best(&kc.layout);
        let (expect_id, expect_score) = cb.nearest(&q);
        assert_eq!(gid, expect_id);
        assert_eq!(score, expect_score);
        assert_eq!(gid, 23);
    }

    #[test]
    fn search_matches_functional_on_random_queries() {
        let (mut acc, kc, cb) = setup(19); // uneven striping across 4 tiles
        let mut rng = Rng::new(5);
        for _ in 0..5 {
            let q = BinaryHV::random(&mut rng, DIM);
            acc.stage_scratch(&kc.layout, 0, &q);
            acc.reset_search();
            let p = kc.search(0, 19);
            acc.run(&p, ControlMethod::Sopc);
            let (gid, score) = acc.global_best(&kc.layout);
            let (eid, escore) = cb.nearest(&q);
            assert_eq!(score, escore);
            assert_eq!(gid, eid);
        }
    }

    #[test]
    fn weighted_bundle_matches_functional() {
        let (mut acc, kc, cb) = setup(8);
        let groups = vec![
            (vec![Operand::plain(VecRef::Item(0))], 3),
            (vec![Operand::plain(VecRef::Item(1))], -2),
            (
                vec![
                    Operand::plain(VecRef::Item(2)),
                    Operand::plain(VecRef::Item(3)),
                ],
                5,
            ),
        ];
        let p = kc.weighted_bundle(&groups, 2);
        acc.run(&p, ControlMethod::Mopc);
        let got = acc.read_scratch(&kc.layout, 1, 2);
        // functional: sign(3*bip(i0) - 2*bip(i1) + 5*bip(i2^i3))
        let mut expect = BinaryHV::zeros(DIM);
        let b23 = cb.item(2).bind(cb.item(3));
        for bit in 0..DIM {
            let v0 = if cb.item(0).get(bit) { 1i64 } else { -1 };
            let v1 = if cb.item(1).get(bit) { 1i64 } else { -1 };
            let v2 = if b23.get(bit) { 1i64 } else { -1 };
            expect.set(bit, 3 * v0 - 2 * v1 + 5 * v2 >= 0);
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn project_matches_functional_weighted_sum() {
        let (mut acc, kc, cb) = setup(12);
        let mut rng = Rng::new(9);
        let xhat = BinaryHV::random(&mut rng, DIM);
        acc.stage_scratch(&kc.layout, 0, &xhat);
        let factor: Vec<usize> = (0..12).collect();
        let p = kc.project(0, &factor, 1);
        acc.run(&p, ControlMethod::Mopc);
        let got = acc.read_scratch(&kc.layout, 3, 1);
        // functional: sign(sum_g dot(item_g, xhat) * bip(item_g))
        let mut expect = BinaryHV::zeros(DIM);
        let scores: Vec<i64> = factor.iter().map(|&g| cb.item(g).dot(&xhat)).collect();
        for bit in 0..DIM {
            let mut acc_v = 0i64;
            for (g, &s) in factor.iter().zip(&scores) {
                let v = if cb.item(*g).get(bit) { 1i64 } else { -1 };
                acc_v += s * v;
            }
            expect.set(bit, acc_v >= 0);
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn sopc_mopc_agree_on_all_kernels() {
        let (acc0, kc, _) = setup(16);
        let mut rng = Rng::new(11);
        let q = BinaryHV::random(&mut rng, DIM);
        for prog in [
            kc.bind(
                &[
                    Operand::plain(VecRef::Item(1)),
                    Operand::plain(VecRef::Item(2)),
                ],
                1,
            ),
            kc.search(0, 16),
            kc.project(0, &[0, 1, 2, 3], 1),
        ] {
            let mut a = acc0.clone();
            let mut b = acc0.clone();
            a.stage_scratch(&kc.layout, 0, &q);
            b.stage_scratch(&kc.layout, 0, &q);
            a.reset_search();
            b.reset_search();
            a.run(&prog, ControlMethod::Sopc);
            b.run(&prog, ControlMethod::Mopc);
            for t in 0..a.cfg.n_tiles {
                assert_eq!(a.tiles[t].sram, b.tiles[t].sram, "{}", prog.label);
                assert_eq!(a.tiles[t].best, b.tiles[t].best);
                assert_eq!(a.tiles[t].dsum_rf, b.tiles[t].dsum_rf);
            }
        }
    }

    #[test]
    fn search_scales_with_dsum_regs_and_tiles() {
        // Acc8 must need strictly fewer words than Acc2 for the same search.
        let mut rng = Rng::new(13);
        let cb = BinaryCodebook::random(&mut rng, 64, DIM);
        let mut words = Vec::new();
        for cfg in [AccelConfig::acc2(), AccelConfig::acc8()] {
            let mut acc = Accelerator::new(cfg.clone());
            let layout = acc.load_items(cb.items(), 4);
            let kc = KernelCompiler::new(cfg, layout);
            words.push(kc.search(0, 64).len());
        }
        assert!(
            words[1] * 3 < words[0],
            "Acc8 search should be ≥3x fewer words: {words:?}"
        );
    }
}
