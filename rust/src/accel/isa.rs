//! Instruction-set architecture: the wide *Instruction Word* macro format
//! (Fig. 10) with one Type field per pipeline stage (Fig. 8) plus an
//! OP_PARAM configuration field, and the SOPC/MOPC control methods
//! (Sec. VI-D).

/// The seven pipeline stages, in dataflow order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// MCG: SRAM / CA-90 / register-file access.
    Mem = 0,
    /// MCG: query register & permutation network.
    Qry = 1,
    /// VOP: XOR binding against the bind buffer.
    Bind = 2,
    /// VOP: binary→integer conversion and scalar multiply.
    Mult = 3,
    /// VOP: integer bundling accumulation (BND RF).
    Bnd = 4,
    /// VOP/DC boundary: SGN bipolarization or POPCNT distance.
    Sgn = 5,
    /// DC: DSUM partial-distance accumulation and ARGMAX search.
    Dc = 6,
}

/// Number of pipeline stages.
pub const N_STAGES: usize = 7;

/// All stages in order.
pub const STAGES: [Stage; N_STAGES] = [
    Stage::Mem,
    Stage::Qry,
    Stage::Bind,
    Stage::Mult,
    Stage::Bnd,
    Stage::Sgn,
    Stage::Dc,
];

/// Stage-1 (MEM) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemOp {
    #[default]
    Nop,
    /// Load fold at `param.addr` from tile SRAM onto the binary datapath.
    LoadSram,
    /// Load CA-90 RF entry `param.rf` onto the datapath.
    LoadRf,
    /// Apply one CA-90 generation to RF entry `param.rf`, put the result
    /// on the datapath, and write it back to the RF (fold regeneration).
    Ca90Gen,
    /// Store the SGN result register to SRAM at `param.addr`.
    StoreResult,
    /// Load the SGN result register onto the datapath.
    LoadResult,
    /// Copy SRAM fold at `param.addr` into CA-90 RF entry `param.rf`
    /// (seeding the RF for on-the-fly regeneration).
    SramToRf,
    /// Store the *previous word's* datapath latch to SRAM at `param.addr`
    /// (MEM is stage 1, so the latch still holds the prior word's value —
    /// how bound binary results reach memory without a BND/SGN pass).
    StoreDatapath,
}

/// Stage-2 (QRY) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QryOp {
    #[default]
    Nop,
    /// Latch the current datapath fold into the QRY register.
    SetQry,
    /// Cyclically permute the datapath fold by `param.shift` bits.
    Permute,
}

/// Stage-3 (BIND) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BindOp {
    #[default]
    Nop,
    /// Latch the datapath fold into the bind buffer.
    SetBuf,
    /// XOR the datapath fold with the bind buffer.
    Xor,
}

/// Stage-4 (MULT) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MultOp {
    #[default]
    Nop,
    /// Convert binary fold to bipolar integer lanes (+1/-1).
    B2I,
    /// B2I then multiply lanes by the scalar weight in `param.weight`.
    Scale,
    /// B2I then multiply by the tile's last DSUM value (resonator
    /// weighting: n_i = d(a_i, x_hat) feeds the projection).
    ScaleByDsum,
}

/// Stage-5 (BND) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BndOp {
    #[default]
    Nop,
    /// Accumulate integer lanes into BND RF entry `param.rf2`.
    Accum,
    /// Zero BND RF entry `param.rf2`, then accumulate.
    ResetAccum,
}

/// Stage-6 (SGN / POPCNT) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SgnOp {
    #[default]
    Nop,
    /// Bipolarize BND RF entry `param.rf2` into the result register.
    Sign,
    /// POPCNT distance of (datapath fold ⊕ QRY): pushes the fold's
    /// bipolar-dot partial value to the DC stage.
    Popcnt,
}

/// Stage-7 (DC) operation type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DcOp {
    #[default]
    Nop,
    /// DSUM RF `param.dsum` += incoming partial distance.
    DsumAcc,
    /// Zero DSUM RF `param.dsum`, then accumulate.
    DsumReset,
    /// Compare DSUM RF `param.dsum` against the tile's running best;
    /// record `param.item` on improvement (nearest-neighbor search).
    ArgmaxUpdate,
    /// Latch DSUM RF `param.dsum` into the tile's "last distance" latch
    /// (feeds `MultOp::ScaleByDsum`).
    DsumLatch,
}

/// OP_PARAM field: configuration shared by the word's stage operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct OpParam {
    /// SRAM fold address (MEM ops).
    pub addr: usize,
    /// CA-90 RF index.
    pub rf: usize,
    /// BND RF index.
    pub rf2: usize,
    /// DSUM RF index.
    pub dsum: usize,
    /// Permutation shift (QRY stage).
    pub shift: i32,
    /// Scalar weight (MULT stage).
    pub weight: i32,
    /// Item identifier for ARGMAX bookkeeping.
    pub item: u32,
    /// Active-tile bitmask (bit t = tile t executes this word).
    pub tile_mask: u64,
}

impl OpParam {
    /// Param with all tiles active.
    pub fn all_tiles() -> Self {
        OpParam {
            tile_mask: u64::MAX,
            ..Default::default()
        }
    }

    /// Param targeting a single tile.
    pub fn tile(t: usize) -> Self {
        OpParam {
            tile_mask: 1u64 << t,
            ..Default::default()
        }
    }
}

/// A wide instruction word: one operation per stage + OP_PARAM (Fig. 10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct InstructionWord {
    pub mem: MemOp,
    pub qry: QryOp,
    pub bind: BindOp,
    pub mult: MultOp,
    pub bnd: BndOp,
    pub sgn: SgnOp,
    pub dc: DcOp,
    pub param: OpParam,
}

impl InstructionWord {
    /// Number of active (non-NOP) stage operations — the SOPC cycle cost.
    pub fn active_stages(&self) -> usize {
        (self.mem != MemOp::Nop) as usize
            + (self.qry != QryOp::Nop) as usize
            + (self.bind != BindOp::Nop) as usize
            + (self.mult != MultOp::Nop) as usize
            + (self.bnd != BndOp::Nop) as usize
            + (self.sgn != SgnOp::Nop) as usize
            + (self.dc != DcOp::Nop) as usize
    }

    /// Whether the word uses only shared-VOP stages (serializes even in a
    /// multi-tile configuration).
    pub fn uses_vop(&self) -> bool {
        self.bind != BindOp::Nop || self.mult != MultOp::Nop || self.bnd != BndOp::Nop
    }

    /// Encoded bit width: 7 Type fields (Fig. 10: 2–3 bits each) + the
    /// 57-bit OP_PARAM = 76 bits total.
    pub const ENCODED_BITS: usize = 57 + 3 + 3 + 3 + 2 + 3 + 3 + 2;
}

/// Accelerator control method (Sec. VI-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ControlMethod {
    /// Single-operation-per-cycle: one stage switches per cycle — simple
    /// control, low power, long runtime.
    Sopc,
    /// Multiple-operations-per-cycle: the pipeline streams words so all
    /// stages operate concurrently — higher throughput and power.
    Mopc,
}

impl std::fmt::Display for ControlMethod {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlMethod::Sopc => write!(f, "SOPC"),
            ControlMethod::Mopc => write!(f, "MOPC"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_word_has_no_active_stages() {
        assert_eq!(InstructionWord::default().active_stages(), 0);
    }

    #[test]
    fn active_stage_count() {
        let w = InstructionWord {
            mem: MemOp::LoadSram,
            sgn: SgnOp::Popcnt,
            dc: DcOp::DsumAcc,
            ..Default::default()
        };
        assert_eq!(w.active_stages(), 3);
        assert!(!w.uses_vop());
    }

    #[test]
    fn vop_detection() {
        let w = InstructionWord {
            mem: MemOp::LoadSram,
            bind: BindOp::Xor,
            ..Default::default()
        };
        assert!(w.uses_vop());
    }

    #[test]
    fn word_format_matches_fig10() {
        // 57-bit OP_PARAM + (3+3+3+2+3+3+2) Type bits = 76.
        assert_eq!(InstructionWord::ENCODED_BITS, 76);
    }

    #[test]
    fn tile_masks() {
        assert_eq!(OpParam::tile(3).tile_mask, 0b1000);
        assert_eq!(OpParam::all_tiles().tile_mask, u64::MAX);
    }
}
