//! Instruction-word programs and occupancy statistics.

use super::isa::{InstructionWord, N_STAGES};

/// A straight-line program of instruction words (control flow is resolved
/// by the kernel compiler; the hardware streams words).
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub words: Vec<InstructionWord>,
    /// Human-readable label for reports.
    pub label: String,
}

impl Program {
    pub fn new(label: impl Into<String>) -> Self {
        Program {
            words: Vec::new(),
            label: label.into(),
        }
    }

    pub fn push(&mut self, w: InstructionWord) {
        self.words.push(w);
    }

    pub fn extend(&mut self, other: &Program) {
        self.words.extend_from_slice(&other.words);
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Total active stage operations (the SOPC cycle count).
    pub fn total_ops(&self) -> usize {
        self.words.iter().map(|w| w.active_stages()).sum()
    }

    /// Mean stage occupancy per word — the theoretical MOPC speedup over
    /// SOPC (Fig. 9's 1.8–2.3× band for the resonator workload).
    pub fn mean_occupancy(&self) -> f64 {
        if self.words.is_empty() {
            return 0.0;
        }
        self.total_ops() as f64 / self.words.len() as f64
    }

    /// Histogram of active-stage counts (0..=7) for occupancy analysis.
    pub fn occupancy_histogram(&self) -> [usize; N_STAGES + 1] {
        let mut h = [0usize; N_STAGES + 1];
        for w in &self.words {
            h[w.active_stages()] += 1;
        }
        h
    }

    /// Fraction of words touching the shared VOP (serializing work).
    pub fn vop_fraction(&self) -> f64 {
        if self.words.is_empty() {
            return 0.0;
        }
        self.words.iter().filter(|w| w.uses_vop()).count() as f64 / self.words.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::{DcOp, MemOp, SgnOp};

    fn search_word() -> InstructionWord {
        InstructionWord {
            mem: MemOp::LoadSram,
            sgn: SgnOp::Popcnt,
            dc: DcOp::DsumAcc,
            ..Default::default()
        }
    }

    #[test]
    fn occupancy_accounting() {
        let mut p = Program::new("t");
        p.push(search_word());
        p.push(InstructionWord {
            mem: MemOp::LoadSram,
            ..Default::default()
        });
        assert_eq!(p.total_ops(), 4);
        assert!((p.mean_occupancy() - 2.0).abs() < 1e-12);
        let h = p.occupancy_histogram();
        assert_eq!(h[3], 1);
        assert_eq!(h[1], 1);
    }

    #[test]
    fn extend_concatenates() {
        let mut a = Program::new("a");
        a.push(search_word());
        let mut b = Program::new("b");
        b.push(search_word());
        b.extend(&a);
        assert_eq!(b.len(), 2);
    }
}
