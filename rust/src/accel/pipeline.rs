//! Program execution: functional semantics + cycle/energy accounting
//! under the SOPC and MOPC control methods.
//!
//! Both control methods execute words in program order with identical
//! architectural results; they differ only in cycle accounting:
//!
//! - **SOPC**: one stage-operation per cycle → `cycles = Σ active_stages`.
//! - **MOPC**: one word enters the pipeline per cycle; all stages operate
//!   concurrently → `cycles = n_words + depth - 1`.
//!
//! Energy = dynamic (per stage-op event, tile-replicated for MCG/DC,
//! single for shared VOP) + control (per cycle) + leakage (per second).

use super::config::AccelConfig;
use super::energy::EnergyModel;
use super::isa::{
    BindOp, BndOp, ControlMethod, DcOp, InstructionWord, MemOp, MultOp, QryOp,
    SgnOp, N_STAGES,
};
use super::program::Program;
use super::tile::{popcnt_partial, Tile, VopState};
use crate::vsa::hypervector::BinaryHV;

/// Item placement after [`Accelerator::load_items`]: items are striped
/// round-robin across tiles; scratch vector slots sit above the item
/// region at the same local address on every tile.
#[derive(Debug, Clone)]
pub struct Layout {
    pub folds_per_vec: usize,
    pub n_items: usize,
    pub n_tiles: usize,
    /// First scratch fold address (uniform across tiles).
    pub scratch_base: usize,
}

impl Layout {
    pub fn tile_of(&self, item: usize) -> usize {
        item % self.n_tiles
    }

    pub fn local_of(&self, item: usize) -> usize {
        item / self.n_tiles
    }

    /// Fold address of local item `local`.
    pub fn local_addr(&self, local: usize) -> usize {
        local * self.folds_per_vec
    }

    /// Items resident on tile `t`.
    pub fn items_on_tile(&self, t: usize) -> usize {
        (self.n_items + self.n_tiles - 1 - t) / self.n_tiles
    }

    /// Max items on any tile (tile 0).
    pub fn max_items_per_tile(&self) -> usize {
        self.items_on_tile(0)
    }

    /// Global item id from (tile, local index).
    pub fn global_id(&self, tile: usize, local: usize) -> usize {
        local * self.n_tiles + tile
    }

    /// Fold address of scratch slot `slot`.
    pub fn scratch_addr(&self, slot: usize) -> usize {
        self.scratch_base + slot * self.folds_per_vec
    }
}

/// Execution report for one program run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub label: String,
    pub control: ControlMethod,
    pub words: usize,
    pub stage_ops: usize,
    pub cycles: u64,
    pub time_s: f64,
    pub dynamic_j: f64,
    pub control_j: f64,
    pub leakage_j: f64,
}

impl SimReport {
    /// Total energy (dynamic + control + leakage).
    pub fn energy_j(&self) -> f64 {
        self.dynamic_j + self.control_j + self.leakage_j
    }

    /// Average power over the run.
    pub fn avg_power_w(&self) -> f64 {
        if self.time_s > 0.0 {
            self.energy_j() / self.time_s
        } else {
            0.0
        }
    }

    /// Merge another report (sequential composition).
    pub fn merge(&mut self, other: &SimReport) {
        self.words += other.words;
        self.stage_ops += other.stage_ops;
        self.cycles += other.cycles;
        self.time_s += other.time_s;
        self.dynamic_j += other.dynamic_j;
        self.control_j += other.control_j;
        self.leakage_j += other.leakage_j;
    }
}

/// The multi-tile VSA accelerator instance.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub cfg: AccelConfig,
    pub energy: EnergyModel,
    pub tiles: Vec<Tile>,
    pub vop: VopState,
}

impl Accelerator {
    pub fn new(cfg: AccelConfig) -> Self {
        let tiles = (0..cfg.n_tiles).map(|_| Tile::new(&cfg)).collect();
        let vop = VopState::new(&cfg);
        Accelerator {
            energy: EnergyModel::default(),
            tiles,
            vop,
            cfg,
        }
    }

    /// Folds per hypervector of dimension `dim`.
    pub fn folds_for(&self, dim: usize) -> usize {
        assert_eq!(
            dim % self.cfg.bus_width,
            0,
            "dim {dim} must be a multiple of the {}-bit bus",
            self.cfg.bus_width
        );
        dim / self.cfg.bus_width
    }

    /// Load an item codebook into tile SRAMs (host DMA, not simulated
    /// cycles — the paper's SRAMs are "initialized with randomly generated
    /// atomic vectors"). Returns the placement.
    pub fn load_items(&mut self, items: &[BinaryHV], scratch_slots: usize) -> Layout {
        assert!(!items.is_empty());
        let dim = items[0].dim();
        let fpv = self.folds_for(dim);
        let layout = Layout {
            folds_per_vec: fpv,
            n_items: items.len(),
            n_tiles: self.cfg.n_tiles,
            scratch_base: {
                let max_local = (items.len() + self.cfg.n_tiles - 1) / self.cfg.n_tiles;
                max_local * fpv
            },
        };
        let capacity = self.tiles[0].sram_folds();
        assert!(
            layout.scratch_base + scratch_slots * fpv <= capacity,
            "codebook + scratch ({} folds) exceeds tile SRAM ({} folds)",
            layout.scratch_base + scratch_slots * fpv,
            capacity
        );
        for (g, item) in items.iter().enumerate() {
            assert_eq!(item.dim(), dim);
            let t = layout.tile_of(g);
            let base = layout.local_addr(layout.local_of(g));
            for f in 0..fpv {
                let w = item.fold(f);
                self.tiles[t].write_sram_fold(base + f, w);
            }
        }
        layout
    }

    /// Stage a vector into scratch slot `slot` on every tile (broadcast
    /// DMA — e.g. a query arriving from the host or the neural frontend).
    pub fn stage_scratch(&mut self, layout: &Layout, slot: usize, v: &BinaryHV) {
        let fpv = layout.folds_per_vec;
        assert_eq!(self.folds_for(v.dim()), fpv);
        let base = layout.scratch_addr(slot);
        for t in 0..self.cfg.n_tiles {
            for f in 0..fpv {
                self.tiles[t].write_sram_fold(base + f, v.fold(f));
            }
        }
    }

    /// Read a vector back from tile `t`'s scratch slot.
    pub fn read_scratch(&self, layout: &Layout, tile: usize, slot: usize) -> BinaryHV {
        let fpv = layout.folds_per_vec;
        let base = layout.scratch_addr(slot);
        let mut words = Vec::with_capacity(fpv * self.cfg.fold_words());
        for f in 0..fpv {
            words.extend_from_slice(self.tiles[tile].sram_fold(base + f));
        }
        BinaryHV::from_words(fpv * self.cfg.bus_width, words)
    }

    /// Reset every tile's DC search state.
    pub fn reset_search(&mut self) {
        for t in &mut self.tiles {
            t.reset_search();
        }
    }

    /// Merge per-tile ARGMAX results into the global nearest item.
    /// Returns (global item id, score).
    pub fn global_best(&self, layout: &Layout) -> (usize, i64) {
        let mut best = (usize::MAX, i64::MIN);
        for (t, tile) in self.tiles.iter().enumerate() {
            let (score, local) = tile.best;
            if local == u32::MAX {
                continue;
            }
            let gid = layout.global_id(t, local as usize);
            if gid >= layout.n_items {
                continue;
            }
            if score > best.1 || (score == best.1 && gid < best.0) {
                best = (gid, score);
            }
        }
        best
    }

    /// Execute a program under the given control method.
    pub fn run(&mut self, prog: &Program, control: ControlMethod) -> SimReport {
        let mut dynamic = 0.0;
        let mut stage_ops = 0usize;
        for w in &prog.words {
            let n_active = self.execute_word(w);
            dynamic += self.energy.word_energy(w, n_active);
            stage_ops += w.active_stages();
        }
        let cycles = match control {
            ControlMethod::Sopc => stage_ops as u64,
            ControlMethod::Mopc => (prog.words.len() + N_STAGES - 1) as u64,
        };
        let time_s = cycles as f64 * self.cfg.cycle_time();
        SimReport {
            label: prog.label.clone(),
            control,
            words: prog.words.len(),
            stage_ops,
            cycles,
            time_s,
            dynamic_j: dynamic,
            control_j: cycles as f64 * self.energy.control_per_cycle,
            leakage_j: time_s * self.cfg.leakage_w(),
        }
    }

    /// Functional semantics of one word. Returns the number of active
    /// tiles (for energy accounting).
    ///
    /// Perf note (§Perf): this is the simulator's per-cycle inner loop —
    /// no heap allocation happens here; all fold moves are
    /// `copy_from_slice` into pre-sized buffers (4.5× word throughput vs.
    /// the initial clone-based version, see EXPERIMENTS.md).
    fn execute_word(&mut self, w: &InstructionWord) -> usize {
        let n_tiles = self.cfg.n_tiles;
        debug_assert!(
            !w.uses_vop() && w.sgn != SgnOp::Sign
                || (w.param.tile_mask & ((1u64 << n_tiles) - 1)).count_ones() == 1,
            "shared-VOP words must target exactly one tile: {w:?}"
        );
        let bus = self.cfg.bus_width;
        let fw = self.cfg.fold_words();
        let vop = &mut self.vop;
        let mut n_active = 0usize;
        for (t, tile) in self.tiles.iter_mut().enumerate() {
            if (w.param.tile_mask >> t) & 1 == 0 {
                continue;
            }
            n_active += 1;
            // --- Stage 1: MEM ------------------------------------------------
            match w.mem {
                MemOp::Nop => {}
                MemOp::LoadSram => {
                    let a = w.param.addr * fw;
                    for i in 0..fw {
                        tile.datapath[i] = tile.sram[a + i];
                    }
                }
                MemOp::LoadRf => {
                    tile.datapath.copy_from_slice(&tile.ca90_rf[w.param.rf]);
                }
                MemOp::Ca90Gen => {
                    tile.ca90_generate(w.param.rf, bus);
                }
                MemOp::StoreResult => {
                    tile.write_sram_fold(w.param.addr, &vop.result);
                }
                MemOp::LoadResult => {
                    tile.datapath.copy_from_slice(&vop.result);
                }
                MemOp::SramToRf => {
                    let a = w.param.addr * fw;
                    for i in 0..fw {
                        tile.datapath[i] = tile.sram[a + i];
                    }
                    tile.ca90_rf[w.param.rf].copy_from_slice(&tile.datapath);
                }
                MemOp::StoreDatapath => {
                    let a = w.param.addr * fw;
                    for i in 0..fw {
                        tile.sram[a + i] = tile.datapath[i];
                    }
                }
            }
            // --- Stage 2: QRY ------------------------------------------------
            match w.qry {
                QryOp::Nop => {}
                QryOp::SetQry => {
                    tile.qry.copy_from_slice(&tile.datapath);
                }
                QryOp::Permute => {
                    let rotated = rotate_fold(&tile.datapath, bus, w.param.shift);
                    tile.datapath.copy_from_slice(&rotated);
                }
            }
            // --- Stage 3: BIND (shared VOP) ----------------------------------
            match w.bind {
                BindOp::Nop => {}
                BindOp::SetBuf => {
                    vop.bind_buf.copy_from_slice(&tile.datapath);
                }
                BindOp::Xor => {
                    for (d, b) in tile.datapath.iter_mut().zip(&vop.bind_buf) {
                        *d ^= *b;
                    }
                }
            }
            // --- Stages 4+5: MULT → BND (shared VOP) --------------------------
            // When both stages are active in one word (the common encode
            // pattern) the lane loops fuse into a single pass.
            let mult_weight = match w.mult {
                MultOp::Nop => None,
                MultOp::B2I => Some(1i64),
                MultOp::Scale => Some(w.param.weight as i64),
                MultOp::ScaleByDsum => Some(tile.dsum_latch),
            };
            match (mult_weight, w.bnd) {
                (Some(wt), BndOp::Accum) => {
                    vop.fused_scale_accum(&tile.datapath, wt, w.param.rf2, false);
                }
                (Some(wt), BndOp::ResetAccum) => {
                    vop.fused_scale_accum(&tile.datapath, wt, w.param.rf2, true);
                }
                (Some(wt), BndOp::Nop) => {
                    vop.b2i(&tile.datapath);
                    if wt != 1 {
                        vop.scale(wt);
                    }
                }
                (None, BndOp::Accum) => vop.accum(w.param.rf2, false),
                (None, BndOp::ResetAccum) => vop.accum(w.param.rf2, true),
                (None, BndOp::Nop) => {}
            }
            // --- Stage 6: SGN / POPCNT ----------------------------------------
            let mut partial: Option<i64> = None;
            match w.sgn {
                SgnOp::Nop => {}
                SgnOp::Sign => vop.sign(w.param.rf2),
                SgnOp::Popcnt => {
                    partial = Some(popcnt_partial(&tile.datapath, &tile.qry, bus));
                }
            }
            // --- Stage 7: DC ---------------------------------------------------
            match w.dc {
                DcOp::Nop => {}
                DcOp::DsumAcc => {
                    tile.dsum_rf[w.param.dsum] += partial.unwrap_or(0);
                }
                DcOp::DsumReset => {
                    tile.dsum_rf[w.param.dsum] = partial.unwrap_or(0);
                }
                DcOp::DsumLatch => {
                    tile.dsum_latch = tile.dsum_rf[w.param.dsum];
                }
                DcOp::ArgmaxUpdate => {
                    let score = tile.dsum_rf[w.param.dsum];
                    if score > tile.best.0
                        || (score == tile.best.0 && w.param.item < tile.best.1)
                    {
                        tile.best = (score, w.param.item);
                    }
                }
            }
        }
        n_active
    }
}

/// Rotate a fold (bus_width-bit ring) left by `shift` bits.
pub fn rotate_fold(fold: &[u64], bus_width: usize, shift: i32) -> Vec<u64> {
    let d = bus_width as i64;
    let s = (((shift as i64 % d) + d) % d) as usize;
    if s == 0 {
        return fold.to_vec();
    }
    let n = fold.len();
    let mut out = vec![0u64; n];
    let word_shift = s / 64;
    let bit_shift = (s % 64) as u32;
    for i in 0..n {
        let dst = (i + word_shift) % n;
        if bit_shift == 0 {
            out[dst] |= fold[i];
        } else {
            out[dst] |= fold[i] << bit_shift;
            out[(dst + 1) % n] |= fold[i] >> (64 - bit_shift);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::OpParam;
    use crate::util::Rng;

    fn setup(n_items: usize, dim: usize) -> (Accelerator, Layout, Vec<BinaryHV>) {
        let mut acc = Accelerator::new(AccelConfig::acc4());
        let mut rng = Rng::new(42);
        let items: Vec<BinaryHV> = (0..n_items).map(|_| BinaryHV::random(&mut rng, dim)).collect();
        let layout = acc.load_items(&items, 8);
        (acc, layout, items)
    }

    #[test]
    fn layout_striping() {
        let (_, layout, _) = setup(10, 4096);
        assert_eq!(layout.tile_of(0), 0);
        assert_eq!(layout.tile_of(5), 1);
        assert_eq!(layout.local_of(5), 1);
        assert_eq!(layout.global_id(1, 1), 5);
        assert_eq!(layout.items_on_tile(0), 3);
        assert_eq!(layout.items_on_tile(3), 2);
    }

    #[test]
    fn items_stored_and_readable() {
        let (acc, layout, items) = setup(6, 4096);
        for g in [0usize, 3, 5] {
            let t = layout.tile_of(g);
            let base = layout.local_addr(layout.local_of(g));
            for f in 0..layout.folds_per_vec {
                assert_eq!(acc.tiles[t].sram_fold(base + f), items[g].fold(f));
            }
        }
    }

    #[test]
    fn scratch_roundtrip() {
        let (mut acc, layout, _) = setup(4, 4096);
        let mut rng = Rng::new(7);
        let v = BinaryHV::random(&mut rng, 4096);
        acc.stage_scratch(&layout, 2, &v);
        for t in 0..acc.cfg.n_tiles {
            assert_eq!(acc.read_scratch(&layout, t, 2), v);
        }
    }

    #[test]
    fn load_and_store_words_roundtrip() {
        let (mut acc, layout, items) = setup(4, 4096);
        // load item 0 fold 0 on tile 0 then store to scratch slot 0
        let mut p = Program::new("copy");
        p.push(InstructionWord {
            mem: MemOp::LoadSram,
            param: OpParam {
                addr: layout.local_addr(0),
                tile_mask: 1,
                ..Default::default()
            },
            ..Default::default()
        });
        p.push(InstructionWord {
            mem: MemOp::StoreDatapath,
            param: OpParam {
                addr: layout.scratch_addr(0),
                tile_mask: 1,
                ..Default::default()
            },
            ..Default::default()
        });
        acc.run(&p, ControlMethod::Sopc);
        assert_eq!(
            acc.tiles[0].sram_fold(layout.scratch_addr(0)),
            items[0].fold(0)
        );
    }

    #[test]
    fn sopc_and_mopc_same_state_different_cycles() {
        let (mut acc_a, layout, items) = setup(4, 4096);
        let mut acc_b = acc_a.clone();
        let mut p = Program::new("bind2");
        // bind items 0 and... stage two scratch vectors and XOR via VOP.
        let mut rng = Rng::new(9);
        let x = BinaryHV::random(&mut rng, 4096);
        acc_a.stage_scratch(&layout, 0, &x);
        acc_b.stage_scratch(&layout, 0, &x);
        for f in 0..layout.folds_per_vec {
            p.push(InstructionWord {
                mem: MemOp::LoadSram,
                bind: BindOp::SetBuf,
                param: OpParam {
                    addr: layout.scratch_addr(0) + f,
                    tile_mask: 1,
                    ..Default::default()
                },
                ..Default::default()
            });
            p.push(InstructionWord {
                mem: MemOp::LoadSram,
                bind: BindOp::Xor,
                param: OpParam {
                    addr: layout.local_addr(0) + f,
                    tile_mask: 1,
                    ..Default::default()
                },
                ..Default::default()
            });
            p.push(InstructionWord {
                mem: MemOp::StoreDatapath,
                param: OpParam {
                    addr: layout.scratch_addr(1) + f,
                    tile_mask: 1,
                    ..Default::default()
                },
                ..Default::default()
            });
        }
        let ra = acc_a.run(&p, ControlMethod::Sopc);
        let rb = acc_b.run(&p, ControlMethod::Mopc);
        // identical architectural state
        assert_eq!(
            acc_a.read_scratch(&layout, 0, 1),
            acc_b.read_scratch(&layout, 0, 1)
        );
        // functional result = XOR bind
        assert_eq!(acc_a.read_scratch(&layout, 0, 1), x.bind(&items[0]));
        // MOPC strictly fewer cycles, same dynamic energy
        assert!(rb.cycles < ra.cycles);
        assert!((ra.dynamic_j - rb.dynamic_j).abs() < 1e-18);
    }

    #[test]
    fn rotate_fold_matches_binaryhv_permute() {
        let mut rng = Rng::new(11);
        let v = BinaryHV::random(&mut rng, 512);
        for shift in [1i32, 63, 64, 200, 511] {
            let rotated = rotate_fold(v.words(), 512, shift);
            let expect = v.permute(shift as i64);
            assert_eq!(&rotated[..], expect.words(), "shift {shift}");
        }
    }

    #[test]
    fn ca90_gen_word_regenerates_folds() {
        let (mut acc, layout, items) = setup(2, 4096);
        // seed RF 0 with item 0's fold 0, then generate fold 1
        let mut p = Program::new("ca90");
        p.push(InstructionWord {
            mem: MemOp::SramToRf,
            param: OpParam {
                addr: layout.local_addr(0),
                rf: 0,
                tile_mask: 1,
                ..Default::default()
            },
            ..Default::default()
        });
        p.push(InstructionWord {
            mem: MemOp::Ca90Gen,
            param: OpParam {
                rf: 0,
                tile_mask: 1,
                ..Default::default()
            },
            ..Default::default()
        });
        acc.run(&p, ControlMethod::Mopc);
        let expect = crate::vsa::ca90::ca90_step(items[0].fold(0), 512);
        assert_eq!(acc.tiles[0].datapath, expect);
        let _ = layout;
    }

    #[test]
    fn report_energy_components() {
        let (mut acc, layout, _) = setup(4, 4096);
        let mut p = Program::new("probe");
        p.push(InstructionWord {
            mem: MemOp::LoadSram,
            param: OpParam {
                addr: layout.local_addr(0),
                tile_mask: 0b1111,
                ..Default::default()
            },
            ..Default::default()
        });
        let r = acc.run(&p, ControlMethod::Sopc);
        assert!(r.dynamic_j > 0.0);
        assert!(r.control_j > 0.0);
        assert!(r.leakage_j > 0.0);
        assert!(r.avg_power_w() > 0.0);
        // 4 tiles active → 4x sram read energy
        assert!((r.dynamic_j - 4.0 * acc.energy.sram_read).abs() < 1e-18);
    }
}
