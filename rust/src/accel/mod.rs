//! Cycle-level simulator of the paper's multi-tile VSA accelerator
//! (Sec. VI: Fig. 7 architecture, Fig. 8 pipeline, Fig. 10 ISA, Tab. VI
//! configurations).
//!
//! Architecture model:
//! - **MCG** (per tile): local SRAM holding codebook folds, CA-90 logic +
//!   register file for on-the-fly fold regeneration, and a QRY register.
//! - **VOP** (shared): BIND (XOR on binary folds), MULT (binary→integer
//!   conversion + scalar multiply), BND (integer bundling accumulators),
//!   BND RF, SGN (bipolarize back to binary).
//! - **DC** (per tile): POPCNT over (fold ⊕ QRY), DSUM RF partial-distance
//!   accumulators, ARGMAX nearest-neighbor tracking.
//!
//! Instructions are wide *Instruction Words*: one operation slot per
//! pipeline stage plus an OP_PARAM field (Fig. 10). Words are broadcast
//! SIMD across the active tile mask — MCG/DC work distributes across
//! tiles, VOP work serializes through the shared datapath, which is
//! exactly why search-heavy REACT scales with tile count while
//! VOP-intensive MULT does not (Fig. 11a).
//!
//! Control methods (Sec. VI-D): **SOPC** issues one stage-operation per
//! cycle; **MOPC** pipelines words so all stages switch concurrently.
//! Both produce identical architectural state — property-tested in
//! `rust/tests/accel_invariants.rs`.

pub mod compiler;
pub mod config;
pub mod energy;
pub mod isa;
pub mod pipeline;
pub mod program;
pub mod tile;

pub use compiler::KernelCompiler;
pub use config::AccelConfig;
pub use energy::EnergyModel;
pub use isa::{ControlMethod, InstructionWord, OpParam, Stage};
pub use pipeline::{Accelerator, SimReport};
pub use program::Program;
