//! Event-based energy model for the VSA accelerator (28 nm class).
//!
//! Per-activation energies are set from published 28 nm hyperdimensional
//! processor figures ([15], [60], [61]): SRAM fold access dominates, logic
//! (XOR/popcount) is cheap, integer accumulate in between.  Control/clock
//! energy is charged per *cycle*, which is what separates SOPC from MOPC
//! power (Sec. VI-D, Fig. 9): MOPC finishes the same dynamic-op energy in
//! fewer cycles (paying less control + leakage energy) but concentrates it
//! into less time — net average power rises ~40–60%.
//!
//! Energy is split into a **per-tile** part (MCG + DC stages, replicated
//! across the active tile mask) and a **shared** part (the single VOP
//! datapath), mirroring the Fig. 7 floorplan.

use super::isa::{
    BindOp, BndOp, DcOp, InstructionWord, MemOp, MultOp, QryOp, SgnOp,
};

/// Per-event energies in joules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// SRAM read of one 512-bit fold.
    pub sram_read: f64,
    /// SRAM write of one fold.
    pub sram_write: f64,
    /// One CA-90 generation (XOR + shifts) over a fold.
    pub ca90_step: f64,
    /// Register-file read/write (CA-90 RF, QRY latch).
    pub rf_access: f64,
    /// 512-lane XOR bind.
    pub xor_bind: f64,
    /// Permutation network pass.
    pub permute: f64,
    /// Binary→integer conversion (512 lanes).
    pub b2i: f64,
    /// Integer scalar multiply (512 lanes).
    pub int_mult: f64,
    /// Integer accumulate into BND RF (512 lanes).
    pub bnd_accum: f64,
    /// Bipolarization of an accumulator.
    pub sgn: f64,
    /// POPCNT over a fold.
    pub popcnt: f64,
    /// DSUM accumulate.
    pub dsum: f64,
    /// ARGMAX compare/update.
    pub argmax: f64,
    /// Control / clock-tree / instruction-decode energy per cycle.
    pub control_per_cycle: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            sram_read: 15e-12,
            sram_write: 18e-12,
            ca90_step: 2e-12,
            rf_access: 1.5e-12,
            xor_bind: 1e-12,
            permute: 1.2e-12,
            b2i: 2e-12,
            int_mult: 8e-12,
            bnd_accum: 6e-12,
            sgn: 1e-12,
            popcnt: 3e-12,
            dsum: 0.5e-12,
            argmax: 0.3e-12,
            control_per_cycle: 10e-12,
        }
    }
}

impl EnergyModel {
    /// Energy of the word's per-tile stages (MCG + DC) for ONE tile.
    pub fn tile_energy(&self, w: &InstructionWord) -> f64 {
        let mut e = 0.0;
        e += match w.mem {
            MemOp::Nop => 0.0,
            MemOp::LoadSram => self.sram_read,
            MemOp::LoadRf | MemOp::LoadResult => self.rf_access,
            MemOp::Ca90Gen => self.ca90_step + self.rf_access,
            MemOp::StoreResult | MemOp::StoreDatapath => self.sram_write,
            MemOp::SramToRf => self.sram_read + self.rf_access,
        };
        e += match w.qry {
            QryOp::Nop => 0.0,
            QryOp::SetQry => self.rf_access,
            QryOp::Permute => self.permute,
        };
        // POPCNT is per-tile (DC front-end); SGN::Sign is shared VOP.
        if w.sgn == SgnOp::Popcnt {
            e += self.popcnt + self.xor_bind;
        }
        e += match w.dc {
            DcOp::Nop => 0.0,
            DcOp::DsumAcc | DcOp::DsumReset | DcOp::DsumLatch => self.dsum,
            DcOp::ArgmaxUpdate => self.argmax,
        };
        e
    }

    /// Energy of the word's shared-VOP stages.
    pub fn shared_energy(&self, w: &InstructionWord) -> f64 {
        let mut e = 0.0;
        e += match w.bind {
            BindOp::Nop => 0.0,
            BindOp::SetBuf => self.rf_access,
            BindOp::Xor => self.xor_bind,
        };
        e += match w.mult {
            MultOp::Nop => 0.0,
            MultOp::B2I => self.b2i,
            MultOp::Scale | MultOp::ScaleByDsum => self.b2i + self.int_mult,
        };
        e += match w.bnd {
            BndOp::Nop => 0.0,
            BndOp::Accum | BndOp::ResetAccum => self.bnd_accum,
        };
        if w.sgn == SgnOp::Sign {
            e += self.sgn;
        }
        e
    }

    /// Total dynamic energy of one word executed on `n_tiles` tiles.
    pub fn word_energy(&self, w: &InstructionWord, n_tiles: usize) -> f64 {
        self.tile_energy(w) * n_tiles as f64 + self.shared_energy(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::OpParam;

    #[test]
    fn nop_word_costs_nothing() {
        let m = EnergyModel::default();
        assert_eq!(m.word_energy(&InstructionWord::default(), 4), 0.0);
    }

    #[test]
    fn search_word_energy_dominated_by_sram() {
        let m = EnergyModel::default();
        let w = InstructionWord {
            mem: MemOp::LoadSram,
            sgn: SgnOp::Popcnt,
            dc: DcOp::DsumAcc,
            param: OpParam::all_tiles(),
            ..Default::default()
        };
        let e = m.word_energy(&w, 1);
        assert!(m.sram_read / e > 0.5, "SRAM should dominate: {e:.2e}");
        // per-tile stages replicate across tiles
        assert!((m.word_energy(&w, 4) - 4.0 * e).abs() < 1e-18);
    }

    #[test]
    fn vop_energy_does_not_scale_with_tiles() {
        let m = EnergyModel::default();
        let w = InstructionWord {
            bind: BindOp::Xor,
            mult: MultOp::Scale,
            bnd: BndOp::Accum,
            ..Default::default()
        };
        assert_eq!(m.word_energy(&w, 1), m.word_energy(&w, 8));
    }

    #[test]
    fn energies_positive_and_ordered() {
        let m = EnergyModel::default();
        assert!(m.sram_read > m.popcnt);
        assert!(m.popcnt > m.dsum);
        assert!(m.int_mult > m.xor_bind);
    }
}
