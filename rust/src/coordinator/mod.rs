//! Neural/symbolic phase coordinator: execution-graph scheduling,
//! critical-path analysis (Fig. 4), and end-to-end pipeline metrics.
//!
//! Rust owns the event loop: neural phases execute as PJRT artifacts,
//! symbolic phases as native engines; independent phases run on worker
//! threads (Recommendation 5's parallel neural/symbolic scheduling).

pub mod graph;
pub mod metrics;
pub mod scheduler;

pub use graph::{CriticalPath, ExecGraph, PhaseNode};
pub use metrics::PhaseMetrics;
pub use scheduler::Scheduler;
