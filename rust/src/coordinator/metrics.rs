//! Phase-level wall-clock metrics collected by the scheduler.

use crate::profiler::taxonomy::PhaseKind;
use std::time::Instant;

/// One executed phase's measurement.
#[derive(Debug, Clone)]
pub struct PhaseRecord {
    pub name: String,
    pub kind: PhaseKind,
    pub wall_s: f64,
}

/// Aggregated phase metrics for an end-to-end run.
#[derive(Debug, Clone, Default)]
pub struct PhaseMetrics {
    pub records: Vec<PhaseRecord>,
}

impl PhaseMetrics {
    pub fn record(&mut self, name: impl Into<String>, kind: PhaseKind, wall_s: f64) {
        self.records.push(PhaseRecord {
            name: name.into(),
            kind,
            wall_s,
        });
    }

    /// Time a closure and record it.
    pub fn time<T>(
        &mut self,
        name: impl Into<String>,
        kind: PhaseKind,
        f: impl FnOnce() -> T,
    ) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, kind, t0.elapsed().as_secs_f64());
        out
    }

    pub fn total(&self) -> f64 {
        self.records.iter().map(|r| r.wall_s).sum()
    }

    pub fn phase_total(&self, kind: PhaseKind) -> f64 {
        self.records
            .iter()
            .filter(|r| r.kind == kind)
            .map(|r| r.wall_s)
            .sum()
    }

    /// Measured symbolic runtime share (the e2e analogue of Fig. 2a).
    pub fn symbolic_fraction(&self) -> f64 {
        let t = self.total();
        if t > 0.0 {
            self.phase_total(PhaseKind::Symbolic) / t
        } else {
            0.0
        }
    }

    /// Pretty per-phase report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            s.push_str(&format!(
                "  {:<28} {:>9} [{}]\n",
                r.name,
                crate::util::stats::fmt_time(r.wall_s),
                r.kind.label()
            ));
        }
        s.push_str(&format!(
            "  total {} — neural {:.1}%, symbolic {:.1}%\n",
            crate::util::stats::fmt_time(self.total()),
            (1.0 - self.symbolic_fraction()) * 100.0,
            self.symbolic_fraction() * 100.0
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let mut m = PhaseMetrics::default();
        m.record("frontend", PhaseKind::Neural, 0.2);
        m.record("reason", PhaseKind::Symbolic, 0.8);
        assert!((m.total() - 1.0).abs() < 1e-12);
        assert!((m.symbolic_fraction() - 0.8).abs() < 1e-12);
        assert!(m.report().contains("frontend"));
    }

    #[test]
    fn time_measures_closures() {
        let mut m = PhaseMetrics::default();
        let v = m.time("work", PhaseKind::Symbolic, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(m.records[0].wall_s >= 0.004);
    }
}
