//! Execution graphs over workload phases + critical-path analysis
//! (the paper's Fig. 4 operator-graph / dataflow study).

use crate::profiler::taxonomy::PhaseKind;
use crate::profiler::trace::Trace;
use crate::platform::Platform;

/// A phase node in the coordinator's execution graph.
#[derive(Debug, Clone)]
pub struct PhaseNode {
    pub name: String,
    pub kind: PhaseKind,
    /// Modelled (or measured) duration in seconds.
    pub duration: f64,
    /// Indices of prerequisite phases.
    pub deps: Vec<usize>,
}

/// A DAG of phases.
#[derive(Debug, Clone, Default)]
pub struct ExecGraph {
    pub nodes: Vec<PhaseNode>,
}

/// Critical-path analysis result.
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalPath {
    /// Node indices on the path, in execution order.
    pub path: Vec<usize>,
    /// Path duration (= minimum makespan with unlimited parallelism).
    pub length: f64,
    /// Total work (sum of all durations).
    pub work: f64,
    /// Seconds of symbolic work on the path.
    pub symbolic_on_path: f64,
}

impl ExecGraph {
    pub fn add(
        &mut self,
        name: impl Into<String>,
        kind: PhaseKind,
        duration: f64,
        deps: &[usize],
    ) -> usize {
        for &d in deps {
            assert!(d < self.nodes.len(), "forward dependency");
        }
        self.nodes.push(PhaseNode {
            name: name.into(),
            kind,
            duration,
            deps: deps.to_vec(),
        });
        self.nodes.len() - 1
    }

    /// Build a phase graph from an operator trace on a platform: each op
    /// becomes a node with its modelled time.
    pub fn from_trace(trace: &Trace, platform: &Platform) -> ExecGraph {
        let mut g = ExecGraph::default();
        for op in &trace.ops {
            g.nodes.push(PhaseNode {
                name: op.name.clone(),
                kind: op.phase,
                duration: platform.op_time(op),
                deps: op.deps.clone(),
            });
        }
        g
    }

    /// Longest path through the DAG (nodes are in topological order).
    pub fn critical_path(&self) -> CriticalPath {
        let n = self.nodes.len();
        let mut dist = vec![0.0f64; n];
        let mut pred: Vec<Option<usize>> = vec![None; n];
        for i in 0..n {
            let base = self.nodes[i]
                .deps
                .iter()
                .map(|&d| (dist[d], Some(d)))
                .fold((0.0, None), |acc, x| if x.0 > acc.0 { x } else { acc });
            dist[i] = base.0 + self.nodes[i].duration;
            pred[i] = base.1;
        }
        let end = (0..n)
            .max_by(|&a, &b| dist[a].partial_cmp(&dist[b]).unwrap())
            .unwrap_or(0);
        let mut path = Vec::new();
        let mut cur = Some(end);
        while let Some(i) = cur {
            path.push(i);
            cur = pred[i];
        }
        path.reverse();
        let symbolic_on_path = path
            .iter()
            .filter(|&&i| self.nodes[i].kind == PhaseKind::Symbolic)
            .map(|&i| self.nodes[i].duration)
            .sum();
        CriticalPath {
            length: dist[end],
            work: self.nodes.iter().map(|p| p.duration).sum(),
            symbolic_on_path,
            path,
        }
    }

    /// Parallelism profile: work / critical-path length (≥ 1.0).
    pub fn parallelism(&self) -> f64 {
        let cp = self.critical_path();
        if cp.length > 0.0 {
            cp.work / cp.length
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_critical_path_is_total() {
        let mut g = ExecGraph::default();
        let a = g.add("n1", PhaseKind::Neural, 1.0, &[]);
        let b = g.add("s1", PhaseKind::Symbolic, 2.0, &[a]);
        g.add("s2", PhaseKind::Symbolic, 3.0, &[b]);
        let cp = g.critical_path();
        assert_eq!(cp.path, vec![0, 1, 2]);
        assert!((cp.length - 6.0).abs() < 1e-12);
        assert!((cp.symbolic_on_path - 5.0).abs() < 1e-12);
        assert!((g.parallelism() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diamond_takes_longer_branch() {
        let mut g = ExecGraph::default();
        let a = g.add("src", PhaseKind::Neural, 1.0, &[]);
        let b = g.add("fast", PhaseKind::Neural, 1.0, &[a]);
        let c = g.add("slow", PhaseKind::Symbolic, 5.0, &[a]);
        g.add("sink", PhaseKind::Symbolic, 1.0, &[b, c]);
        let cp = g.critical_path();
        assert_eq!(cp.path, vec![0, 2, 3]);
        assert!((cp.length - 7.0).abs() < 1e-12);
        assert!(g.parallelism() > 1.0);
    }

    #[test]
    fn from_trace_mirrors_dependencies() {
        use crate::profiler::taxonomy::OpCategory;
        let mut tr = Trace::new("x");
        let a = tr.add("conv", OpCategory::Conv, PhaseKind::Neural, 1 << 24, 1 << 20, 1 << 20, &[]);
        tr.add("bind", OpCategory::VectorElem, PhaseKind::Symbolic, 1 << 10, 1 << 16, 1 << 16, &[a]);
        let g = ExecGraph::from_trace(&tr, &Platform::rtx2080ti());
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.nodes[1].deps, vec![0]);
        let cp = g.critical_path();
        assert_eq!(cp.path.len(), 2);
    }

    /// Fig. 4's headline: for the frontend-dependent workloads the
    /// symbolic phase sits on the critical path.
    #[test]
    fn nvsa_symbolic_dominates_critical_path() {
        let w = crate::workloads::nvsa::Nvsa::default();
        let g = ExecGraph::from_trace(
            &crate::workloads::Workload::trace(&w),
            &Platform::rtx2080ti(),
        );
        let cp = g.critical_path();
        assert!(
            cp.symbolic_on_path / cp.length > 0.5,
            "symbolic share of critical path: {}",
            cp.symbolic_on_path / cp.length
        );
    }
}
