//! Phase scheduler: topological execution of an [`ExecGraph`] with
//! level-parallel dispatch across worker threads (Recommendation 5:
//! "adaptive workload scheduling with parallelism processing of neural
//! and symbolic components").
//!
//! Tasks are closures keyed by graph node; independent nodes in the same
//! topological level run concurrently via `std::thread::scope`.

use super::graph::ExecGraph;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Scheduler over an execution graph.
pub struct Scheduler {
    pub graph: ExecGraph,
}

/// Result of a scheduled run.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    /// Wall-clock makespan of the whole run.
    pub makespan_s: f64,
    /// Per-node wall time, indexed like the graph.
    pub node_wall_s: Vec<f64>,
    /// Topological levels executed (each level ran in parallel).
    pub levels: Vec<Vec<usize>>,
}

impl Scheduler {
    pub fn new(graph: ExecGraph) -> Scheduler {
        Scheduler { graph }
    }

    /// Group nodes into topological levels (Kahn layering).
    pub fn levels(&self) -> Vec<Vec<usize>> {
        let n = self.graph.nodes.len();
        let mut level = vec![0usize; n];
        for i in 0..n {
            for &d in &self.graph.nodes[i].deps {
                level[i] = level[i].max(level[d] + 1);
            }
        }
        let max_level = level.iter().copied().max().unwrap_or(0);
        let mut out = vec![Vec::new(); max_level + 1];
        for (i, &l) in level.iter().enumerate() {
            out[l].push(i);
        }
        out
    }

    /// Execute `tasks[node] = closure` respecting dependencies; nodes in
    /// the same level run on scoped threads.
    pub fn run(&self, tasks: HashMap<usize, Box<dyn Fn() + Send + Sync>>) -> ScheduleOutcome {
        let levels = self.levels();
        let n = self.graph.nodes.len();
        let wall = Mutex::new(vec![0.0f64; n]);
        let t0 = Instant::now();
        for level in &levels {
            std::thread::scope(|scope| {
                for &i in level {
                    if let Some(task) = tasks.get(&i) {
                        let wall = &wall;
                        scope.spawn(move || {
                            let s = Instant::now();
                            task();
                            wall.lock().unwrap()[i] = s.elapsed().as_secs_f64();
                        });
                    }
                }
            });
        }
        ScheduleOutcome {
            makespan_s: t0.elapsed().as_secs_f64(),
            node_wall_s: wall.into_inner().unwrap(),
            levels,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::taxonomy::PhaseKind;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn diamond() -> ExecGraph {
        let mut g = ExecGraph::default();
        let a = g.add("a", PhaseKind::Neural, 1.0, &[]);
        let b = g.add("b", PhaseKind::Neural, 1.0, &[a]);
        let c = g.add("c", PhaseKind::Symbolic, 1.0, &[a]);
        g.add("d", PhaseKind::Symbolic, 1.0, &[b, c]);
        g
    }

    #[test]
    fn levels_respect_dependencies() {
        let s = Scheduler::new(diamond());
        let levels = s.levels();
        assert_eq!(levels, vec![vec![0], vec![1, 2], vec![3]]);
    }

    #[test]
    fn run_executes_all_tasks_in_order() {
        let s = Scheduler::new(diamond());
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut tasks: HashMap<usize, Box<dyn Fn() + Send + Sync>> = HashMap::new();
        for i in 0..4 {
            let order = order.clone();
            tasks.insert(
                i,
                Box::new(move || {
                    order.lock().unwrap().push(i);
                }),
            );
        }
        let out = s.run(tasks);
        let seq = order.lock().unwrap().clone();
        assert_eq!(seq.len(), 4);
        assert_eq!(seq[0], 0);
        assert_eq!(*seq.last().unwrap(), 3);
        assert_eq!(out.node_wall_s.len(), 4);
        assert!(out.makespan_s > 0.0);
    }

    #[test]
    fn parallel_level_overlaps() {
        // two 20ms tasks in the same level should take < 35ms total
        let s = Scheduler::new(diamond());
        let counter = Arc::new(AtomicUsize::new(0));
        let mut tasks: HashMap<usize, Box<dyn Fn() + Send + Sync>> = HashMap::new();
        for i in [1usize, 2] {
            let c = counter.clone();
            tasks.insert(
                i,
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    c.fetch_add(1, Ordering::SeqCst);
                }),
            );
        }
        let t0 = std::time::Instant::now();
        s.run(tasks);
        let el = t0.elapsed().as_millis();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
        assert!(el < 36, "parallel level took {el} ms");
    }
}
