//! # nscog — Neuro-Symbolic AI Workload Characterization & VSA Acceleration
//!
//! Reproduction of *"Towards Efficient Neuro-Symbolic AI: From Workload
//! Characterization to Hardware Architecture"* (Wan et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time)**: `python/compile/` authors the Pallas VSA
//!   kernels and the seven workloads' neural compute graphs, AOT-lowered
//!   to HLO text in `artifacts/`.
//! - **L3 (this crate)**: the systems contribution — VSA substrate
//!   ([`vsa`]), cycle-level multi-tile VSA accelerator simulator
//!   ([`accel`]), the seven neuro-symbolic workload models ([`workloads`]),
//!   the characterization profiler ([`profiler`]), analytical platform cost
//!   models ([`platform`]), the PJRT runtime bridge ([`runtime`]), the
//!   neural/symbolic phase coordinator ([`coordinator`]), and the sharded,
//!   dynamically-batched query serving engine ([`serve`]).
//!
//! Python never runs on the request path: artifacts are compiled once by
//! `make artifacts` and executed from Rust via the PJRT C API.
//!
//! See `DESIGN.md` for the experiment index mapping every paper figure and
//! table to a module and a bench target.

pub mod accel;
pub mod config;
pub mod figures;
pub mod coordinator;
pub mod platform;
pub mod profiler;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod vsa;
pub mod workloads;
