//! Free-standing VSA algebra: circular convolution / correlation (HRR
//! binding used by NVSA), batched similarity, and the kernel-calculus
//! helpers mirroring the paper's sub-functions a/b/c/d/e (Sec. VI-B).

use super::hypervector::RealHV;
use crate::util::fft;

/// Circular convolution binding: `z[i] = sum_j x[j] * y[(i - j) mod D]`.
///
/// For power-of-two `D` this dispatches to the O(D log D) FFT path in
/// [`crate::util::fft`]; other dimensions (and the equivalence property
/// tests) use the direct O(D²) evaluation in [`circular_conv_direct`].
pub fn circular_conv(x: &RealHV, y: &RealHV) -> RealHV {
    let d = x.dim();
    assert_eq!(d, y.dim());
    if d.is_power_of_two() {
        return RealHV::from_vec(fft::cconv_pow2(x.as_slice(), y.as_slice()));
    }
    circular_conv_direct(x, y)
}

/// Direct O(D²) circular convolution — reference implementation and
/// fallback for non-power-of-two dimensions.
pub fn circular_conv_direct(x: &RealHV, y: &RealHV) -> RealHV {
    let d = x.dim();
    assert_eq!(d, y.dim());
    let xs = x.as_slice();
    let ys = y.as_slice();
    let mut out = vec![0.0f32; d];
    for (j, &xj) in xs.iter().enumerate() {
        if xj == 0.0 {
            continue;
        }
        // z[i] += x[j] * y[i - j mod d]; iterate i-j = k → i = j + k.
        let (head, tail) = ys.split_at(d - j);
        // i from j..d uses y[0..d-j]
        for (k, &yk) in head.iter().enumerate() {
            out[j + k] += xj * yk;
        }
        // i from 0..j uses y[d-j..d]
        for (k, &yk) in tail.iter().enumerate() {
            out[k] += xj * yk;
        }
    }
    RealHV::from_vec(out)
}

/// Circular correlation (approximate unbinding of [`circular_conv`]):
/// `z[i] = sum_j x[j] * y[(j + i) mod D]`.
///
/// Power-of-two `D` uses the FFT path (`Z = conj(X)·Y`); other dimensions
/// fall back to [`circular_corr_direct`].
pub fn circular_corr(x: &RealHV, y: &RealHV) -> RealHV {
    let d = x.dim();
    assert_eq!(d, y.dim());
    if d.is_power_of_two() {
        return RealHV::from_vec(fft::ccorr_pow2(x.as_slice(), y.as_slice()));
    }
    circular_corr_direct(x, y)
}

/// Direct O(D²) circular correlation — reference implementation and
/// fallback for non-power-of-two dimensions.
pub fn circular_corr_direct(x: &RealHV, y: &RealHV) -> RealHV {
    let d = x.dim();
    assert_eq!(d, y.dim());
    let xs = x.as_slice();
    let ys = y.as_slice();
    let mut out = vec![0.0f32; d];
    for i in 0..d {
        let mut acc = 0.0f32;
        for j in 0..d {
            let idx = j + i;
            let idx = if idx >= d { idx - d } else { idx };
            acc += xs[j] * ys[idx];
        }
        out[i] = acc;
    }
    RealHV::from_vec(out)
}

/// Bundle (sum) a slice of hypervectors: paper's `a(y, (1, s2))`.
pub fn bundle(vs: &[&RealHV]) -> RealHV {
    assert!(!vs.is_empty());
    let mut out = RealHV::zeros(vs[0].dim());
    for v in vs {
        out.add_assign(v);
    }
    out
}

/// Bind a sequence with Hadamard products: paper's `b(y, (s2=1))`.
pub fn bind_all(vs: &[&RealHV]) -> RealHV {
    assert!(!vs.is_empty());
    let mut out = vs[0].clone();
    for v in &vs[1..] {
        out = out.bind(v);
    }
    out
}

/// Positional binding: `x_1 (*) rho(x_2) (*) rho^2(x_3) ...` — paper's
/// `b(y, (s2=3))`, preserving sequence order.
pub fn bind_positional(vs: &[&RealHV]) -> RealHV {
    assert!(!vs.is_empty());
    let mut out = vs[0].clone();
    for (j, v) in vs.iter().enumerate().skip(1) {
        out = out.bind(&v.permute(j as i64));
    }
    out
}

/// Weighted sum c(y) = sum_i n_i * y_i — the resonator projection
/// kernel, routed through the dispatched SIMD `axpy` (bit-identical to
/// the scalar loop on every tier).
pub fn weighted_sum(weights: &[f32], vs: &[&RealHV]) -> RealHV {
    assert_eq!(weights.len(), vs.len());
    assert!(!vs.is_empty());
    let d = vs[0].dim();
    let mut out = vec![0.0f32; d];
    for (w, v) in weights.iter().zip(vs) {
        if *w == 0.0 {
            continue;
        }
        crate::vsa::kernels::axpy_f32(&mut out, *w, v.as_slice());
    }
    RealHV::from_vec(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_res;
    use crate::util::Rng;

    // The old `naive_cconv` helper had a nonsense index expression and was
    // dead outside this module; the inline O(D²) sums below are the naive
    // oracle now.

    #[test]
    fn cconv_matches_naive() {
        // z[i] = sum_j x[j] y[(i-j) mod d]; half the cases draw a
        // power-of-two dim (FFT path), half an arbitrary dim (direct
        // fallback), so both sides face the independent naive oracle.
        forall_res(300, 20, |r| {
            let d = if r.below(2) == 0 { 16usize << r.below(3) } else { 16 + r.below(48) };
            let x: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            (x, y)
        }, |(x, y)| {
            let d = x.len();
            let fast = circular_conv(&RealHV::from_vec(x.clone()), &RealHV::from_vec(y.clone()));
            for i in 0..d {
                let mut acc = 0.0f64;
                for j in 0..d {
                    acc += x[j] as f64 * y[(i + d - j) % d] as f64;
                }
                if (fast.as_slice()[i] as f64 - acc).abs() > 1e-3 {
                    return Err(format!("i={i}: {} vs {}", fast.as_slice()[i], acc));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ccorr_matches_naive() {
        // z[i] = sum_j x[j] y[(j+i) mod d], same forced pow2/non-pow2 mix.
        forall_res(301, 20, |r| {
            let d = if r.below(2) == 0 { 16usize << r.below(3) } else { 16 + r.below(48) };
            let x: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            (x, y)
        }, |(x, y)| {
            let d = x.len();
            let fast = circular_corr(&RealHV::from_vec(x.clone()), &RealHV::from_vec(y.clone()));
            for i in 0..d {
                let mut acc = 0.0f64;
                for j in 0..d {
                    acc += x[j] as f64 * y[(j + i) % d] as f64;
                }
                if (fast.as_slice()[i] as f64 - acc).abs() > 1e-3 {
                    return Err(format!("i={i}: {} vs {}", fast.as_slice()[i], acc));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fft_paths_match_direct_reference() {
        forall_res(302, 12, |r| {
            let d = 64usize << r.below(5); // 64..1024, all powers of two
            let x: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            (x, y)
        }, |(x, y)| {
            let xv = RealHV::from_vec(x.clone());
            let yv = RealHV::from_vec(y.clone());
            for (label, fast, slow) in [
                ("conv", circular_conv(&xv, &yv), circular_conv_direct(&xv, &yv)),
                ("corr", circular_corr(&xv, &yv), circular_corr_direct(&xv, &yv)),
            ] {
                for (i, (a, b)) in fast.as_slice().iter().zip(slow.as_slice()).enumerate() {
                    if (a - b).abs() > 1e-3 {
                        return Err(format!("{label} d={} i={i}: {a} vs {b}", x.len()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cconv_commutative() {
        let mut rng = Rng::new(1);
        let x = RealHV::random_hrr(&mut rng, 256);
        let y = RealHV::random_hrr(&mut rng, 256);
        let a = circular_conv(&x, &y);
        let b = circular_conv(&y, &x);
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn ccorr_unbinds_cconv() {
        let mut rng = Rng::new(2);
        let x = RealHV::random_hrr(&mut rng, 1024);
        let y = RealHV::random_hrr(&mut rng, 1024);
        let z = circular_conv(&x, &y);
        let y_hat = circular_corr(&x, &z);
        assert!(y_hat.cosine(&y) > 0.5, "cos {}", y_hat.cosine(&y));
    }

    #[test]
    fn bundle_preserves_members() {
        let mut rng = Rng::new(3);
        let vs: Vec<RealHV> = (0..4).map(|_| RealHV::random_bipolar(&mut rng, 2048)).collect();
        let refs: Vec<&RealHV> = vs.iter().collect();
        let s = bundle(&refs).sign();
        for v in &vs {
            assert!(s.cosine(v) > 0.25);
        }
    }

    #[test]
    fn bind_positional_order_sensitive() {
        let mut rng = Rng::new(4);
        let a = RealHV::random_bipolar(&mut rng, 2048);
        let b = RealHV::random_bipolar(&mut rng, 2048);
        let ab = bind_positional(&[&a, &b]);
        let ba = bind_positional(&[&b, &a]);
        assert!(ab.cosine(&ba).abs() < 0.1, "order must matter");
        // while plain binding is commutative:
        assert!((bind_all(&[&a, &b]).cosine(&bind_all(&[&b, &a])) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_sum_matches_manual() {
        let a = RealHV::from_vec(vec![1.0, 2.0]);
        let b = RealHV::from_vec(vec![-1.0, 0.5]);
        let out = weighted_sum(&[2.0, 3.0], &[&a, &b]);
        assert_eq!(out.as_slice(), &[-1.0, 5.5]);
    }
}
