//! Hypervector types: bit-packed binary and real-valued (bipolar) vectors.
//!
//! Every word-level hot loop here (XOR bind, bulk popcount Hamming, the
//! majority counter planes, permute's funnel shift, and the canonical f32
//! dot accumulation) routes through the runtime-dispatched SIMD backend
//! in [`super::kernels`], so a single dispatch decision accelerates every
//! scan/sketch/serve layer built on top at bit-identical results.

use super::kernels;
use crate::util::Rng;

pub use super::kernels::DotAcc;

/// Fold width in bits — matches the accelerator's 512-bit global bus
/// (Tab. VI, `W`). A `D`-dimensional binary vector is `D / FOLD_BITS`
/// folds; the accelerator streams one fold per pipeline pass.
pub const FOLD_BITS: usize = 512;
/// `u64` words per fold.
pub const FOLD_WORDS: usize = FOLD_BITS / 64;

/// Dense binary hypervector, bit-packed (LSB-first within each `u64`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BinaryHV {
    dim: usize,
    words: Vec<u64>,
}

impl BinaryHV {
    /// All-zeros vector. `dim` must be a multiple of 64.
    pub fn zeros(dim: usize) -> Self {
        assert!(dim > 0 && dim % 64 == 0, "dim must be a positive multiple of 64");
        BinaryHV {
            dim,
            words: vec![0u64; dim / 64],
        }
    }

    /// Uniform random vector.
    pub fn random(rng: &mut Rng, dim: usize) -> Self {
        let mut hv = Self::zeros(dim);
        for w in &mut hv.words {
            *w = rng.next_u64();
        }
        hv
    }

    /// Build from raw words (must match dim/64).
    pub fn from_words(dim: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), dim / 64);
        assert!(dim % 64 == 0);
        BinaryHV { dim, words }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Number of 512-bit folds.
    pub fn n_folds(&self) -> usize {
        (self.dim + FOLD_BITS - 1) / FOLD_BITS
    }

    /// Borrow fold `k` as a word slice (last fold may be shorter).
    pub fn fold(&self, k: usize) -> &[u64] {
        let a = k * FOLD_WORDS;
        let b = ((k + 1) * FOLD_WORDS).min(self.words.len());
        &self.words[a..b]
    }

    /// Get bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.dim);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.dim);
        let mask = 1u64 << (i % 64);
        if v {
            self.words[i / 64] |= mask;
        } else {
            self.words[i / 64] &= !mask;
        }
    }

    /// XOR binding (self-inverse): the accelerator's BIND unit.
    pub fn bind(&self, other: &BinaryHV) -> BinaryHV {
        let mut out = self.clone();
        out.bind_assign(other);
        out
    }

    /// In-place XOR binding (hot-path variant, no allocation), routed
    /// through the dispatched SIMD XOR kernel.
    pub fn bind_assign(&mut self, other: &BinaryHV) {
        assert_eq!(self.dim, other.dim);
        kernels::xor_into(&mut self.words, &other.words);
    }

    /// Hamming distance (POPCNT of XOR) — per-word reference kernel.
    pub fn hamming(&self, other: &BinaryHV) -> u32 {
        assert_eq!(self.dim, other.dim);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum()
    }

    /// Hamming distance via the dispatched bulk-popcount kernel
    /// ([`kernels::xor_hamming`]): Harley–Seal carry-save on the scalar
    /// tier, nibble-LUT `vpshufb` popcount on AVX2, `vcnt` on NEON. The
    /// batched codebook scans' inner kernel; always equal to `hamming`
    /// (integer popcount partial sums are order-insensitive).
    pub fn hamming_bulk(&self, other: &BinaryHV) -> u32 {
        assert_eq!(self.dim, other.dim);
        kernels::xor_hamming(&self.words, &other.words)
    }

    /// [`Self::dot`] computed with the bulk popcount kernel.
    pub fn dot_bulk(&self, other: &BinaryHV) -> i64 {
        self.dim as i64 - 2 * self.hamming_bulk(other) as i64
    }

    /// Bipolar dot product equivalent: `dim - 2 * hamming` — the quantity
    /// the accelerator's POPCNT unit computes ("difference between the
    /// number of 1's and 0's in the difference vector").
    pub fn dot(&self, other: &BinaryHV) -> i64 {
        self.dim as i64 - 2 * self.hamming(other) as i64
    }

    /// Normalized similarity in [-1, 1].
    pub fn cosine(&self, other: &BinaryHV) -> f64 {
        self.dot(other) as f64 / self.dim as f64
    }

    /// Cyclic permutation by `shift` bit positions (rho^shift).
    ///
    /// Decomposed into a word rotation (two contiguous copies) followed by
    /// the dispatched cyclic funnel shift [`kernels::funnel_shl`], so the
    /// bit half runs 4 words per SIMD op instead of the old scatter of
    /// per-word `|=` pairs. Bit i of the input lands at bit
    /// `(i + s) mod d` of the output, exactly as before.
    pub fn permute(&self, shift: i64) -> BinaryHV {
        let d = self.dim as i64;
        let s = ((shift % d) + d) % d;
        if s == 0 {
            return self.clone();
        }
        let word_shift = (s / 64) as usize;
        let bit_shift = (s % 64) as u32;
        let n = self.words.len();
        let mut out = BinaryHV::zeros(self.dim);
        // word rotation: rot[j] = in[(j - word_shift) mod n]
        out.words[word_shift..].copy_from_slice(&self.words[..n - word_shift]);
        out.words[..word_shift].copy_from_slice(&self.words[n - word_shift..]);
        if bit_shift != 0 {
            kernels::funnel_shl(&mut out.words, bit_shift);
        }
        out
    }

    /// Count of set bits (dispatched bulk popcount).
    pub fn popcount(&self) -> u32 {
        kernels::popcount_words(&self.words)
    }

    /// Fraction of zero bits (sparsity in the characterization sense).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.popcount() as f64 / self.dim as f64
    }
}

/// Majority-vote bundling of binary hypervectors. Ties (even counts) break
/// via a deterministic tie-break vector derived from `tie_seed`.
///
/// Word-parallel implementation: the per-bit counters are held as
/// bit-sliced counter planes in **plane-major** layout
/// (`planes[k * n_words + w]` = bit `k` of the 64 counters covering word
/// `w`), so accumulating one input vector is a short cascade of whole-row
/// carry-save steps through the dispatched SIMD kernel
/// ([`kernels::csa_step`], 4–8 words per op) that stops as soon as the
/// carry row clears. The majority threshold is then evaluated with a
/// row-parallel bit-sliced comparator. Tie columns consume the tie RNG in
/// ascending word/bit order — exactly the order of the per-bit reference —
/// so results are bit-identical to [`majority_ref`] on every tier.
pub fn majority(vs: &[&BinaryHV], tie_seed: u64) -> BinaryHV {
    assert!(!vs.is_empty());
    let dim = vs[0].dim();
    for v in vs {
        assert_eq!(v.dim(), dim);
    }
    let n = vs.len();
    let n_words = dim / 64;
    // p_bits planes represent counts 0..=n.
    let p_bits = usize::BITS as usize - n.leading_zeros() as usize;
    let mut planes = vec![0u64; n_words * p_bits];
    let mut carry = vec![0u64; n_words];
    for v in vs {
        carry.copy_from_slice(v.words());
        let mut cleared = false;
        for k in 0..p_bits {
            let plane = &mut planes[k * n_words..(k + 1) * n_words];
            if kernels::csa_step(plane, &mut carry) {
                cleared = true;
                break;
            }
        }
        debug_assert!(
            cleared || carry.iter().all(|&c| c == 0),
            "planes sized to hold counts up to n"
        );
    }
    // Compare each sliced counter against floor(n/2): strictly greater →
    // bit set; equal (possible only for even n) → tie-break draw.
    let threshold = n / 2;
    let even = n % 2 == 0;
    let mut gt = vec![0u64; n_words];
    let mut eq = vec![!0u64; n_words];
    for k in (0..p_bits).rev() {
        let row = &planes[k * n_words..(k + 1) * n_words];
        if (threshold >> k) & 1 == 1 {
            for (e, &v) in eq.iter_mut().zip(row) {
                *e &= v;
            }
        } else {
            for ((g, e), &v) in gt.iter_mut().zip(eq.iter_mut()).zip(row) {
                *g |= *e & v;
                *e &= !v;
            }
        }
    }
    let mut tie = Rng::new(tie_seed);
    let mut out = BinaryHV::zeros(dim);
    for (w, word) in out.words.iter_mut().enumerate() {
        let mut bits = gt[w];
        if even {
            let mut m = eq[w];
            while m != 0 {
                let b = m.trailing_zeros();
                if tie.next_u64() & 1 == 1 {
                    bits |= 1u64 << b;
                }
                m &= m - 1;
            }
        }
        *word = bits;
    }
    out
}

/// Per-bit reference implementation of [`majority`], retained for
/// equivalence property tests and as the before/after baseline in
/// `benches/hotpath.rs`.
pub fn majority_ref(vs: &[&BinaryHV], tie_seed: u64) -> BinaryHV {
    assert!(!vs.is_empty());
    let dim = vs[0].dim();
    let mut counts = vec![0u32; dim];
    for v in vs {
        assert_eq!(v.dim(), dim);
        for i in 0..dim {
            counts[i] += v.get(i) as u32;
        }
    }
    let mut tie = Rng::new(tie_seed);
    let half2 = vs.len() as u32; // compare 2*count against len
    let mut out = BinaryHV::zeros(dim);
    for i in 0..dim {
        let twice = 2 * counts[i];
        let bit = if twice > half2 {
            true
        } else if twice < half2 {
            false
        } else {
            tie.next_u64() & 1 == 1
        };
        out.set(i, bit);
    }
    out
}

/// Real-valued hypervector (f32 storage), the L1/L2 representation.
#[derive(Debug, Clone, PartialEq)]
pub struct RealHV {
    data: Vec<f32>,
}

impl RealHV {
    pub fn zeros(dim: usize) -> Self {
        RealHV {
            data: vec![0.0; dim],
        }
    }

    /// Random bipolar (+1/-1) vector.
    pub fn random_bipolar(rng: &mut Rng, dim: usize) -> Self {
        RealHV {
            data: (0..dim).map(|_| rng.bipolar()).collect(),
        }
    }

    /// Random unit-variance Gaussian vector scaled by 1/sqrt(D) (HRR init).
    pub fn random_hrr(rng: &mut Rng, dim: usize) -> Self {
        let scale = 1.0 / (dim as f64).sqrt();
        RealHV {
            data: (0..dim).map(|_| (rng.normal() * scale) as f32).collect(),
        }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        RealHV { data }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// In-place Hadamard binding (hot-path variant, no allocation).
    pub fn bind_assign(&mut self, other: &RealHV) {
        assert_eq!(self.dim(), other.dim());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= *b;
        }
    }

    /// Overwrite contents from `other` without reallocating.
    pub fn copy_from(&mut self, other: &RealHV) {
        assert_eq!(self.dim(), other.dim());
        self.data.copy_from_slice(&other.data);
    }

    /// Bipolarize in place: sign with +1 at zero, no allocation.
    pub fn sign_assign(&mut self) {
        for a in self.data.iter_mut() {
            *a = if *a >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// Hadamard binding.
    pub fn bind(&self, other: &RealHV) -> RealHV {
        assert_eq!(self.dim(), other.dim());
        RealHV {
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    /// Elementwise sum bundling.
    pub fn add(&self, other: &RealHV) -> RealHV {
        assert_eq!(self.dim(), other.dim());
        RealHV {
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// In-place accumulate (bundling hot path).
    pub fn add_assign(&mut self, other: &RealHV) {
        assert_eq!(self.dim(), other.dim());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += *b;
        }
    }

    /// Scalar multiplication (the accelerator's MULT unit).
    pub fn scale(&self, w: f32) -> RealHV {
        RealHV {
            data: self.data.iter().map(|a| a * w).collect(),
        }
    }

    /// Bipolarize: sign with +1 at zero (the accelerator's SGN unit).
    pub fn sign(&self) -> RealHV {
        RealHV {
            data: self
                .data
                .iter()
                .map(|&a| if a >= 0.0 { 1.0 } else { -1.0 })
                .collect(),
        }
    }

    /// Dot product in the canonical lane-strided f64 order ([`DotAcc`],
    /// 8 fixed lanes) — the same accumulation the chunked pruned scans
    /// thread through their partial sums and every SIMD tier reproduces,
    /// so a pruned scan's surviving score is bit-identical to this
    /// reference by construction on any tier.
    pub fn dot(&self, other: &RealHV) -> f64 {
        assert_eq!(self.dim(), other.dim());
        let mut acc = DotAcc::new();
        acc.accumulate(&self.data, &other.data);
        acc.value()
    }

    /// Cosine similarity.
    pub fn cosine(&self, other: &RealHV) -> f64 {
        let d = self.dot(other);
        let na = self.dot(self).sqrt();
        let nb = other.dot(other).sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            d / (na * nb)
        }
    }

    /// Cyclic permutation by `shift` positions.
    pub fn permute(&self, shift: i64) -> RealHV {
        let d = self.dim() as i64;
        let s = (((shift % d) + d) % d) as usize;
        let mut data = Vec::with_capacity(self.dim());
        data.extend_from_slice(&self.data[self.dim() - s..]);
        data.extend_from_slice(&self.data[..self.dim() - s]);
        RealHV { data }
    }

    /// Fraction of exact zeros.
    pub fn sparsity(&self) -> f64 {
        let zeros = self.data.iter().filter(|&&x| x == 0.0).count();
        zeros as f64 / self.dim().max(1) as f64
    }

    /// Fraction of entries with |x| < eps (near-zero sparsity).
    pub fn sparsity_eps(&self, eps: f32) -> f64 {
        let zeros = self.data.iter().filter(|&&x| x.abs() < eps).count();
        zeros as f64 / self.dim().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn binary_bind_self_inverse() {
        forall(100, 30, |r| {
            let d = 64 * (1 + r.below(16));
            (BinaryHV::random(r, d), BinaryHV::random(r, d))
        }, |(x, y)| x.bind(&x.bind(y)) == *y);
    }

    #[test]
    fn binary_bind_quasi_orthogonal() {
        let mut rng = Rng::new(1);
        let x = BinaryHV::random(&mut rng, 8192);
        let y = BinaryHV::random(&mut rng, 8192);
        let z = x.bind(&y);
        assert!(z.cosine(&x).abs() < 0.1);
        assert!(z.cosine(&y).abs() < 0.1);
    }

    #[test]
    fn binary_dot_identity() {
        let mut rng = Rng::new(2);
        let x = BinaryHV::random(&mut rng, 1024);
        assert_eq!(x.dot(&x), 1024);
        assert_eq!(x.hamming(&x), 0);
        assert_eq!(x.dot_bulk(&x), 1024);
        assert_eq!(x.hamming_bulk(&x), 0);
    }

    #[test]
    fn hamming_bulk_matches_per_word_reference() {
        // Dims straddle the 16-word Harley–Seal chunk boundary (1024 bits
        // = 16 words) so both the CSA tree and the tail path are hit.
        forall(104, 60, |r| {
            let d = 64 * (1 + r.below(40));
            (BinaryHV::random(r, d), BinaryHV::random(r, d))
        }, |(x, y)| {
            x.hamming_bulk(y) == x.hamming(y) && x.dot_bulk(y) == x.dot(y)
        });
    }

    #[test]
    fn binary_permute_roundtrip() {
        forall(101, 30, |r| {
            let d = 64 * (1 + r.below(8));
            (BinaryHV::random(r, d), r.range(-200, 200))
        }, |(x, s)| x.permute(*s).permute(-*s) == *x);
    }

    #[test]
    fn binary_permute_matches_naive() {
        let mut rng = Rng::new(3);
        let x = BinaryHV::random(&mut rng, 128);
        for shift in [1i64, 63, 64, 65, 127, 128] {
            let fast = x.permute(shift);
            let mut naive = BinaryHV::zeros(128);
            for i in 0..128 {
                naive.set(((i as i64 + shift) % 128) as usize, x.get(i));
            }
            assert_eq!(fast, naive, "shift {shift}");
        }
    }

    #[test]
    fn binary_permute_preserves_popcount() {
        forall(102, 30, |r| {
            let d = 64 * (1 + r.below(8));
            (BinaryHV::random(r, d), r.range(0, 1000))
        }, |(x, s)| x.permute(*s).popcount() == x.popcount());
    }

    #[test]
    fn majority_similar_to_members() {
        let mut rng = Rng::new(4);
        let vs: Vec<BinaryHV> = (0..3).map(|_| BinaryHV::random(&mut rng, 4096)).collect();
        let refs: Vec<&BinaryHV> = vs.iter().collect();
        let m = majority(&refs, 7);
        for v in &vs {
            assert!(m.cosine(v) > 0.3, "cos {}", m.cosine(v));
        }
    }

    #[test]
    fn majority_of_one_is_identity() {
        let mut rng = Rng::new(5);
        let v = BinaryHV::random(&mut rng, 512);
        assert_eq!(majority(&[&v], 0), v);
    }

    #[test]
    fn majority_word_sliced_matches_reference() {
        // Odd and even member counts: even counts exercise the tie-break
        // RNG stream, which must be consumed in the same order.
        forall(103, 40, |r| {
            let d = 64 * (1 + r.below(8));
            let n = 1 + r.below(12);
            let vs: Vec<BinaryHV> = (0..n).map(|_| BinaryHV::random(r, d)).collect();
            (vs, r.next_u64())
        }, |(vs, seed)| {
            let refs: Vec<&BinaryHV> = vs.iter().collect();
            majority(&refs, *seed) == majority_ref(&refs, *seed)
        });
    }

    #[test]
    fn real_bind_self_inverse_bipolar() {
        let mut rng = Rng::new(6);
        let x = RealHV::random_bipolar(&mut rng, 1024);
        let y = RealHV::random_bipolar(&mut rng, 1024);
        let z = x.bind(&x.bind(&y));
        assert_eq!(z, y);
    }

    #[test]
    fn real_sign_is_bipolar() {
        let mut rng = Rng::new(7);
        let x = RealHV::random_hrr(&mut rng, 512);
        let s = x.sign();
        assert!(s.as_slice().iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn real_permute_roundtrip() {
        let mut rng = Rng::new(8);
        let x = RealHV::random_hrr(&mut rng, 300);
        assert_eq!(x.permute(17).permute(-17), x);
        assert_eq!(x.permute(300), x);
    }

    #[test]
    fn dot_acc_chunked_is_bit_identical() {
        // Splitting the accumulation at arbitrary chunk boundaries —
        // including chunks that are not multiples of the 8-lane width —
        // must reproduce the one-pass dot exactly: the invariant the
        // pruned scans' resume-after-sketch path relies on.
        let mut rng = Rng::new(11);
        let x = RealHV::random_hrr(&mut rng, 1100);
        let y = RealHV::random_hrr(&mut rng, 1100);
        let full = x.dot(&y);
        for chunk in [1usize, 7, 13, 64, 512, 1100, 4096] {
            let mut acc = DotAcc::new();
            let mut i = 0;
            while i < 1100 {
                let e = (i + chunk).min(1100);
                acc.accumulate(&x.as_slice()[i..e], &y.as_slice()[i..e]);
                i = e;
            }
            assert_eq!(acc.value().to_bits(), full.to_bits(), "chunk {chunk}");
        }
    }

    #[test]
    fn real_cosine_bounds() {
        let mut rng = Rng::new(9);
        let x = RealHV::random_bipolar(&mut rng, 2048);
        let y = RealHV::random_bipolar(&mut rng, 2048);
        assert!((x.cosine(&x) - 1.0).abs() < 1e-6);
        assert!(x.cosine(&y).abs() < 0.12);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let v = RealHV::from_vec(vec![0.0, 1.0, 0.0, 2.0]);
        assert!((v.sparsity() - 0.5).abs() < 1e-12);
        let b = BinaryHV::zeros(128);
        assert!((b.sparsity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn folds_cover_vector() {
        let mut rng = Rng::new(10);
        let x = BinaryHV::random(&mut rng, 2048);
        assert_eq!(x.n_folds(), 4);
        let total: usize = (0..4).map(|k| x.fold(k).len()).sum();
        assert_eq!(total, x.words().len());
    }
}
