//! Cascaded sketch-prefilter sidecars and prune accounting for the
//! bound-pruned associative scans (paper Sec. V–VI: the cleanup scan is
//! memory-bound, so the win is *streaming fewer item words*, not more
//! arithmetic).
//!
//! A [`BinarySketch`] holds the first `S` bits of every item in one
//! contiguous item-major block; a [`RealSketch`] holds the first chunk of
//! every item plus per-item suffix L2 norms at chunk boundaries. Both give
//! the scan two exact tools:
//!
//! 1. a **prefilter bound** — after reading only the sketch, the best
//!    score an item can still reach is known (`dim - 2·ham_prefix` for
//!    binary; `dot_prefix + ‖rest_item‖·‖rest_query‖` by Cauchy–Schwarz
//!    for real), so items that cannot beat the current k-th best are
//!    rejected before their full rows are touched, and
//! 2. a **scan order** — visiting items most-promising-first makes the
//!    k-th-best threshold tight almost immediately, which is what lets the
//!    incremental per-chunk bound inside the full scan terminate early.
//!
//! Pruning decisions are made under the same (score desc, index asc)
//! total order the exhaustive scans use, so pruned results are
//! **bit-identical** to the reference — an item is skipped only when at
//! least `k` already-scored items provably precede it. See
//! `rust/tests/pruned_equivalence.rs`.
//!
//! Sketches are immutable once built — there is no in-place item update.
//! The serving layer's live mutations (item insert/delete, see
//! `serve::registry`) rebuild the whole codebook **and** its sketch
//! sidecar through `BinaryCodebook::from_items_sketched` and publish the
//! pair as one new immutable snapshot, so a sketch can never disagree
//! with the rows it summarizes: readers either see the old
//! codebook+sketch pair or the new one, never a mix.

use super::ca90;
use super::hypervector::{BinaryHV, RealHV, FOLD_BITS};

/// Default binary sketch width: one 512-bit fold (the accelerator's bus
/// width), used when the vector is long enough for the sidecar to pay for
/// itself; shorter vectors rely on incremental bounds alone.
pub const DEFAULT_SKETCH_BITS: usize = FOLD_BITS;

/// Words per incremental-bound chunk in the pruned binary scans (one
/// 512-bit fold: the granularity the accelerator streams item rows at).
pub const PRUNE_CHUNK_WORDS: usize = 8;

/// Elements per incremental-bound chunk in the pruned real scans.
pub const REAL_PRUNE_CHUNK: usize = 512;

/// Default binary sketch width for a given dimension: one fold when the
/// row is at least four folds long, otherwise no sketch (0).
pub fn default_sketch_bits(dim: usize) -> usize {
    if dim >= 4 * FOLD_BITS {
        DEFAULT_SKETCH_BITS
    } else {
        0
    }
}

/// Default cascade coarse-level width: two words (128 bits). At million-
/// item scale the ordering pass then streams 2 words/item instead of 8,
/// with the fine sketch consulted only for coarse survivors.
pub const DEFAULT_CASCADE_BITS: usize = 128;

/// Per-scan pruning telemetry: how much of the item memory a scan
/// actually streamed versus what an exhaustive scan would have read.
/// Units are `u64` words for binary scans and `f32` elements for real
/// scans; sketch reads count toward `words_streamed`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Items considered across all scans.
    pub items: u64,
    /// Items rejected on the cascade's coarse-level bound alone (neither
    /// the fine sketch remainder nor the full row ever touched). Zero
    /// when no cascade level is configured.
    pub coarse_rejected: u64,
    /// Items rejected on the sketch bound alone (full row never touched).
    pub sketch_rejected: u64,
    /// Full-row scans abandoned mid-row by the incremental bound.
    pub early_terminated: u64,
    /// Words (binary) / elements (real) actually read, sketch included.
    pub words_streamed: u64,
    /// Words an exhaustive scan of the same queries would have read.
    pub words_total: u64,
}

impl PruneStats {
    /// Fold another scan's counters into this one.
    pub fn merge(&mut self, other: &PruneStats) {
        self.items += other.items;
        self.coarse_rejected += other.coarse_rejected;
        self.sketch_rejected += other.sketch_rejected;
        self.early_terminated += other.early_terminated;
        self.words_streamed += other.words_streamed;
        self.words_total += other.words_total;
    }

    /// Fraction of items rejected by the sketch prefilter alone.
    pub fn sketch_reject_rate(&self) -> f64 {
        if self.items > 0 {
            self.sketch_rejected as f64 / self.items as f64
        } else {
            0.0
        }
    }

    /// Fraction of items rejected by the cascade's coarse level alone.
    pub fn coarse_reject_rate(&self) -> f64 {
        if self.items > 0 {
            self.coarse_rejected as f64 / self.items as f64
        } else {
            0.0
        }
    }

    /// Fraction of item-memory words actually streamed. Always ≤ 1.0:
    /// sketch words are the row prefix and full scans resume at the
    /// sketch boundary, so even a fully-scanned item streams exactly its
    /// row (1.0 therefore means "nothing pruned", not "overhead paid" —
    /// the sidecar's cost is extra passes over resident data, never extra
    /// words).
    pub fn words_frac(&self) -> f64 {
        if self.words_total > 0 {
            self.words_streamed as f64 / self.words_total as f64
        } else {
            0.0
        }
    }

    /// Counter-wise difference versus an earlier snapshot of the same
    /// monotonically-growing stats (used to attribute a reused scratch's
    /// accumulated telemetry to one batch).
    pub fn delta_since(&self, earlier: &PruneStats) -> PruneStats {
        PruneStats {
            items: self.items.saturating_sub(earlier.items),
            coarse_rejected: self.coarse_rejected.saturating_sub(earlier.coarse_rejected),
            sketch_rejected: self.sketch_rejected.saturating_sub(earlier.sketch_rejected),
            early_terminated: self.early_terminated.saturating_sub(earlier.early_terminated),
            words_streamed: self.words_streamed.saturating_sub(earlier.words_streamed),
            words_total: self.words_total.saturating_sub(earlier.words_total),
        }
    }
}

/// Contiguous item-major block of each item's first `words_per_item`
/// words — the binary prefilter sidecar. Bits are verbatim copies of the
/// item rows, so a prefix Hamming computed on the sketch equals the same
/// prefix computed on the row.
#[derive(Debug, Clone)]
pub struct BinarySketch {
    words_per_item: usize,
    block: Vec<u64>,
    /// Cascade coarse level: the first `coarse_words` of each item
    /// duplicated into their own contiguous block, so the ordering pass
    /// streams `items · coarse_words` words instead of
    /// `items · words_per_item`. 0 = no cascade (single-level sketch).
    coarse_words: usize,
    coarse_block: Vec<u64>,
}

impl BinarySketch {
    /// Build the sidecar, or `None` when `sketch_bits` is 0 or does not
    /// leave a remainder to prune (sketch must be strictly narrower than
    /// the row). `sketch_bits` is rounded down to whole words.
    pub fn build(items: &[BinaryHV], sketch_bits: usize) -> Option<BinarySketch> {
        let words_per_item = sketch_bits / 64;
        let n_words = items.first()?.words().len();
        if words_per_item == 0 || words_per_item >= n_words {
            return None;
        }
        let mut block = Vec::with_capacity(items.len() * words_per_item);
        for it in items {
            block.extend_from_slice(&it.words()[..words_per_item]);
        }
        Some(BinarySketch {
            words_per_item,
            block,
            coarse_words: 0,
            coarse_block: Vec::new(),
        })
    }

    /// Build the sidecar straight from CA-90 seed folds, without ever
    /// materializing the full item vectors: a sketch no wider than the
    /// seed fold is a verbatim seed prefix, and wider sketches stream
    /// [`ca90::ca90_step_into`] generations chunk-by-chunk into the block
    /// (one ping-pong scratch pair reused across all items). `n_words` is
    /// the full row length in words (`dim / 64`); the same
    /// None-degradation rules as [`Self::build`] apply. Rows produced
    /// this way are word-for-word identical to building from the expanded
    /// items (fused `BinaryCodebook::from_seeds` path; property-tested).
    pub fn build_from_seeds(
        seeds: &[Vec<u64>],
        fold_bits: usize,
        n_words: usize,
        sketch_bits: usize,
    ) -> Option<BinarySketch> {
        let words_per_item = sketch_bits / 64;
        if seeds.is_empty() || words_per_item == 0 || words_per_item >= n_words {
            return None;
        }
        let fw = fold_bits / 64;
        let mut block = Vec::with_capacity(seeds.len() * words_per_item);
        let mut state = vec![0u64; fw];
        let mut next = vec![0u64; fw];
        for seed in seeds {
            assert_eq!(seed.len(), fw);
            let take = words_per_item.min(fw);
            block.extend_from_slice(&seed[..take]);
            let mut written = take;
            if written < words_per_item {
                state.copy_from_slice(seed);
                while written < words_per_item {
                    ca90::ca90_step_into(&state, &mut next, fold_bits);
                    std::mem::swap(&mut state, &mut next);
                    let take = (words_per_item - written).min(fw);
                    block.extend_from_slice(&state[..take]);
                    written += take;
                }
            }
        }
        Some(BinarySketch {
            words_per_item,
            block,
            coarse_words: 0,
            coarse_block: Vec::new(),
        })
    }

    /// Enable the hierarchical cascade: duplicate each item's first
    /// `coarse_bits` (rounded down to whole words) into a contiguous
    /// coarse block that the scans' ordering/bulk-reject pass streams
    /// instead of the full sketch. Returns `false` (cascade left off)
    /// when the width is zero or not strictly narrower than the sketch —
    /// a level as wide as the sketch would stream the same words twice
    /// for nothing. Idempotent: re-enabling rebuilds the block.
    pub fn enable_cascade(&mut self, coarse_bits: usize) -> bool {
        let cw = coarse_bits / 64;
        if cw == 0 || cw >= self.words_per_item {
            self.coarse_words = 0;
            self.coarse_block = Vec::new();
            return false;
        }
        let n = self.block.len() / self.words_per_item;
        let mut coarse = Vec::with_capacity(n * cw);
        for i in 0..n {
            let row = &self.block[i * self.words_per_item..i * self.words_per_item + cw];
            coarse.extend_from_slice(row);
        }
        self.coarse_words = cw;
        self.coarse_block = coarse;
        true
    }

    /// Coarse-level words per item (0 = cascade off).
    pub fn coarse_words(&self) -> usize {
        self.coarse_words
    }

    /// Coarse-level bits per item (0 = cascade off).
    pub fn coarse_bits(&self) -> usize {
        self.coarse_words * 64
    }

    /// Item `i`'s coarse-level words. Panics when the cascade is off.
    #[inline]
    pub fn coarse_row(&self, i: usize) -> &[u64] {
        &self.coarse_block[i * self.coarse_words..(i + 1) * self.coarse_words]
    }

    pub fn words_per_item(&self) -> usize {
        self.words_per_item
    }

    /// Sketch bits per item.
    pub fn bits(&self) -> usize {
        self.words_per_item * 64
    }

    /// Item `i`'s sketch words.
    #[inline]
    pub fn row(&self, i: usize) -> &[u64] {
        &self.block[i * self.words_per_item..(i + 1) * self.words_per_item]
    }

    /// Sidecar memory footprint (bytes), cascade level included.
    pub fn storage_bytes(&self) -> usize {
        (self.block.len() + self.coarse_block.len()) * 8
    }
}

/// Real-valued scan sidecar: the first [`REAL_PRUNE_CHUNK`] elements of
/// each item in one contiguous block (prefilter pass) plus per-item
/// suffix L2 norms at every chunk boundary (Cauchy–Schwarz upper bounds
/// for the incremental scan).
#[derive(Debug, Clone)]
pub struct RealSketch {
    chunk: usize,
    n_chunks: usize,
    prefix: Vec<f32>,
    /// `rest_norms[i * n_chunks + c] = ‖item_i[(c+1)·chunk ..]‖`; the last
    /// entry per item is 0 (nothing follows the final chunk).
    rest_norms: Vec<f64>,
}

impl RealSketch {
    /// Build the sidecar; `None` when the row is a single chunk (no
    /// boundary to bound across).
    pub fn build(items: &[RealHV], chunk: usize) -> Option<RealSketch> {
        let dim = items.first()?.dim();
        let n_chunks = (dim + chunk - 1) / chunk;
        if n_chunks < 2 {
            return None;
        }
        let mut prefix = Vec::with_capacity(items.len() * chunk);
        let mut rest_norms = Vec::with_capacity(items.len() * n_chunks);
        for it in items {
            let v = it.as_slice();
            prefix.extend_from_slice(&v[..chunk]);
            let base = rest_norms.len();
            rest_norms.resize(base + n_chunks, 0.0);
            let mut sumsq = 0.0f64;
            for c in (1..n_chunks).rev() {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(dim);
                for &x in &v[lo..hi] {
                    sumsq += (x as f64) * (x as f64);
                }
                rest_norms[base + c - 1] = sumsq.sqrt();
            }
        }
        Some(RealSketch {
            chunk,
            n_chunks,
            prefix,
            rest_norms,
        })
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    pub fn n_chunks(&self) -> usize {
        self.n_chunks
    }

    /// Item `i`'s prefix chunk.
    #[inline]
    pub fn prefix_row(&self, i: usize) -> &[f32] {
        &self.prefix[i * self.chunk..(i + 1) * self.chunk]
    }

    /// `‖item_i[(c+1)·chunk ..]‖` — the norm of everything *after* chunk
    /// boundary `c`.
    #[inline]
    pub fn rest_norm(&self, i: usize, c: usize) -> f64 {
        self.rest_norms[i * self.n_chunks + c]
    }
}

/// Write the query-side suffix norms (`out[c] = ‖q[(c+1)·chunk ..]‖`)
/// into a reusable buffer; zero allocation once `out` has capacity for
/// `⌈dim/chunk⌉` entries.
pub fn query_suffix_norms(q: &[f32], chunk: usize, out: &mut Vec<f64>) {
    let n_chunks = (q.len() + chunk - 1) / chunk;
    out.clear();
    out.resize(n_chunks, 0.0);
    let mut sumsq = 0.0f64;
    for c in (1..n_chunks).rev() {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(q.len());
        for &x in &q[lo..hi] {
            sumsq += (x as f64) * (x as f64);
        }
        out[c - 1] = sumsq.sqrt();
    }
}

/// Conservative Cauchy–Schwarz upper bound for a partially-scanned real
/// dot product: `acc` is the exact partial, `rest` the norm-product bound
/// on the remainder (≥ 0). The relative inflation absorbs f64 rounding in
/// the norm/bound arithmetic so rounding can never cause a wrongful
/// prune; the exhaustive comparison that *would* have kept the item uses
/// exactly the same canonical lane-strided accumulation
/// ([`crate::vsa::DotAcc`]) as the pruned path, so any surviving item's
/// final score is bit-identical.
#[inline]
pub fn real_upper_bound(acc: f64, rest: f64) -> f64 {
    acc + rest + 1e-9 * (1.0 + acc.abs() + rest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn binary_sketch_rows_mirror_item_prefixes() {
        let mut rng = Rng::new(1);
        let items: Vec<BinaryHV> = (0..9).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
        let sk = BinarySketch::build(&items, 512).unwrap();
        assert_eq!(sk.words_per_item(), 8);
        for (i, it) in items.iter().enumerate() {
            assert_eq!(sk.row(i), &it.words()[..8]);
        }
        // too-wide or zero sketches degrade to None
        assert!(BinarySketch::build(&items, 2048).is_none());
        assert!(BinarySketch::build(&items, 0).is_none());
        assert!(BinarySketch::build(&[], 512).is_none());
    }

    #[test]
    fn seed_built_sketch_matches_item_built_sketch() {
        use crate::vsa::hypervector::FOLD_WORDS;
        let mut rng = Rng::new(4);
        let seeds: Vec<Vec<u64>> = (0..7)
            .map(|_| (0..FOLD_WORDS).map(|_| rng.next_u64()).collect())
            .collect();
        let dim = 4096;
        let items: Vec<BinaryHV> = seeds
            .iter()
            .map(|s| ca90::expand_vector(s, FOLD_BITS, dim))
            .collect();
        // widths below, at, and above one fold — the >fold case streams
        // CA-90 generations into the block
        for bits in [256usize, 512, 1024, 1536] {
            let fused = BinarySketch::build_from_seeds(&seeds, FOLD_BITS, dim / 64, bits)
                .unwrap_or_else(|| panic!("no sketch at {bits}"));
            let direct = BinarySketch::build(&items, bits).unwrap();
            assert_eq!(fused.words_per_item(), direct.words_per_item(), "bits={bits}");
            for i in 0..7 {
                assert_eq!(fused.row(i), direct.row(i), "bits={bits} item {i}");
            }
        }
        // degradation rules mirror build(): zero width, too-wide, empty
        assert!(BinarySketch::build_from_seeds(&seeds, FOLD_BITS, 8, 512).is_none());
        assert!(BinarySketch::build_from_seeds(&seeds, FOLD_BITS, 64, 0).is_none());
        assert!(BinarySketch::build_from_seeds(&[], FOLD_BITS, 64, 512).is_none());
    }

    #[test]
    fn cascade_rows_mirror_sketch_prefixes() {
        let mut rng = Rng::new(11);
        let items: Vec<BinaryHV> = (0..9).map(|_| BinaryHV::random(&mut rng, 4096)).collect();
        let mut sk = BinarySketch::build(&items, 512).unwrap();
        let flat_bytes = sk.storage_bytes();
        assert!(sk.enable_cascade(DEFAULT_CASCADE_BITS));
        assert_eq!(sk.coarse_words(), 2);
        assert_eq!(sk.coarse_bits(), 128);
        for (i, it) in items.iter().enumerate() {
            assert_eq!(sk.coarse_row(i), &it.words()[..2]);
            assert_eq!(sk.coarse_row(i), &sk.row(i)[..2]);
        }
        // duplicate coarse block accounted in the sidecar footprint
        assert_eq!(sk.storage_bytes(), flat_bytes + 9 * 2 * 8);
        // degenerate widths leave the cascade off: zero, sub-word, and
        // a level not strictly narrower than the sketch
        for bad in [0usize, 63, 512, 1024] {
            assert!(!sk.enable_cascade(bad), "bits={bad}");
            assert_eq!(sk.coarse_words(), 0);
        }
        // idempotent re-enable after a disable
        assert!(sk.enable_cascade(128));
        assert_eq!(sk.coarse_row(3), &items[3].words()[..2]);
    }

    #[test]
    fn real_sketch_norms_bound_the_suffix() {
        let mut rng = Rng::new(2);
        let items: Vec<RealHV> = (0..5)
            .map(|_| RealHV::random_hrr(&mut rng, 1280))
            .collect();
        let sk = RealSketch::build(&items, REAL_PRUNE_CHUNK).unwrap();
        assert_eq!(sk.n_chunks(), 3);
        for (i, it) in items.iter().enumerate() {
            assert_eq!(sk.prefix_row(i), &it.as_slice()[..REAL_PRUNE_CHUNK]);
            // final boundary has nothing left
            assert_eq!(sk.rest_norm(i, 2), 0.0);
            // norms decrease along the row and match a direct computation
            let direct: f64 = it.as_slice()[REAL_PRUNE_CHUNK..]
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt();
            assert!((sk.rest_norm(i, 0) - direct).abs() < 1e-9 * (1.0 + direct));
            assert!(sk.rest_norm(i, 0) >= sk.rest_norm(i, 1));
        }
        let single: Vec<RealHV> = vec![RealHV::zeros(256)];
        assert!(RealSketch::build(&single, REAL_PRUNE_CHUNK).is_none());
    }

    #[test]
    fn query_norms_match_item_norms_shape() {
        let mut rng = Rng::new(3);
        let q = RealHV::random_bipolar(&mut rng, 1100);
        let mut out = Vec::new();
        query_suffix_norms(q.as_slice(), REAL_PRUNE_CHUNK, &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(out[2], 0.0);
        // suffix of a bipolar vector of length L has norm sqrt(L)
        assert!((out[0] - (588f64).sqrt()).abs() < 1e-9);
        assert!((out[1] - (76f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn prune_stats_rates() {
        let mut a = PruneStats {
            items: 10,
            coarse_rejected: 3,
            sketch_rejected: 4,
            early_terminated: 2,
            words_streamed: 50,
            words_total: 100,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.items, 20);
        assert_eq!(a.coarse_rejected, 6);
        assert!((a.sketch_reject_rate() - 0.4).abs() < 1e-12);
        assert!((a.coarse_reject_rate() - 0.3).abs() < 1e-12);
        assert!((a.words_frac() - 0.5).abs() < 1e-12);
        assert_eq!(PruneStats::default().words_frac(), 0.0);
        // delta vs an earlier snapshot recovers the later contribution
        assert_eq!(a.delta_since(&b), b);
        assert_eq!(a.delta_since(&a), PruneStats::default());
    }
}
