//! Runtime-dispatched SIMD word kernels — the one layer every scan,
//! sketch pass, bundle, and projection above it funnels into.
//!
//! The paper's profiling conclusion (Sec. V–VI) is that vector-symbolic
//! workloads are memory-bound bitwise/word-parallel loops that
//! off-the-shelf hardware never exploits; CogSys (PAPERS.md) shows the
//! win comes from wide bitwise datapaths over hypervector words. PRs 1–3
//! funneled every hot path into a handful of scalar `u64`/f32 word loops;
//! this module gives those loops a wide datapath:
//!
//! - **AVX2** (x86_64, `std::arch` intrinsics): 256-bit XOR/AND/OR, the
//!   Muła nibble-LUT `vpshufb` popcount, 4×f64 lane accumulation;
//! - **NEON** (aarch64): 128-bit bitops, `vcnt`-based popcount, 2×f64
//!   lanes;
//! - **scalar**: the retained PR 1 kernels (Harley–Seal carry-save bulk
//!   popcount and chunked-unrolled loops LLVM can autovectorize) — the
//!   reference every other tier is property-tested against.
//!
//! The tier is selected **once per process** (CPUID /
//! `is_aarch64_feature_detected`, cached in a `OnceLock`) and overridable
//! with `NSCOG_SIMD=scalar|avx2|neon|auto` for A/B benching; `ci.sh` runs
//! the hot-path bench under `scalar` and `auto` and gates the ratio.
//! Hosts with AVX-512-VPOPCNTDQ are detected and reported
//! ([`avx512_popcnt_available`]) but routed through the AVX2 kernels: the
//! `vpopcntdq` intrinsics only recently stabilized in `std::arch` and the
//! repo pins no minimum toolchain, so they stay out until the floor moves.
//!
//! # Exactness contracts
//!
//! Binary kernels are **bit-identical** across tiers by construction:
//! XOR/AND/OR are lane-wise and popcount partial sums are
//! order-insensitive integers.
//!
//! f32 dot products are **exactly equal** across tiers because the
//! canonical summation order is defined here once, as a fixed-width
//! lane-strided accumulation ([`DotAcc`], [`DOT_LANES`] = 8 f64 lanes):
//! element `p` of a row always lands in lane `p % 8`, lanes accumulate
//! sequentially in f64 with separate (unfused) mul/add roundings, and
//! [`DotAcc::value`] reduces lanes left-to-right. Every tier — and every
//! chunk split the bound-pruned scans make — reproduces that exact
//! schedule, so SIMD vs scalar vs resumed-mid-row results match
//! bit-for-bit (property-tested across dims that are not lane multiples).
//! `axpy` is element-wise (no reduction), hence trivially bit-identical.

use std::sync::OnceLock;

/// Number of independent f64 accumulation lanes in the canonical dot
/// product order — fixed across tiers (AVX2 uses two 4-lane registers,
/// NEON four 2-lane registers, scalar an unrolled 8-array).
pub const DOT_LANES: usize = 8;

/// A SIMD dispatch tier. `Scalar` is always supported and is the
/// reference implementation for the equivalence property tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdTier {
    Scalar,
    Avx2,
    Neon,
}

impl SimdTier {
    /// Stable name used by `NSCOG_SIMD`, `nscog info`, and the bench
    /// JSONs' `"simd"` field.
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Whether this host can execute the tier.
    pub fn is_supported(self) -> bool {
        match self {
            SimdTier::Scalar => true,
            SimdTier::Avx2 => avx2_supported(),
            SimdTier::Neon => neon_supported(),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_supported() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_supported() -> bool {
    false
}

/// Whether the host additionally advertises AVX-512-VPOPCNTDQ (reported
/// by `nscog info`; see the module docs for why it routes through AVX2).
pub fn avx512_popcnt_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx512vpopcntdq")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Tiers this host can run, best-first (always ends with `Scalar`).
pub fn available_tiers() -> Vec<SimdTier> {
    let mut out = Vec::with_capacity(2);
    if avx2_supported() {
        out.push(SimdTier::Avx2);
    }
    if neon_supported() {
        out.push(SimdTier::Neon);
    }
    out.push(SimdTier::Scalar);
    out
}

/// Parse an `NSCOG_SIMD` value; `None` means "auto" (including unknown
/// strings, so a typo degrades to the best tier rather than a crash).
pub fn parse_tier(s: &str) -> Option<SimdTier> {
    match s.trim().to_ascii_lowercase().as_str() {
        "scalar" => Some(SimdTier::Scalar),
        "avx2" => Some(SimdTier::Avx2),
        "neon" => Some(SimdTier::Neon),
        _ => None,
    }
}

/// Resolve a requested tier against host support: an explicit request for
/// an unsupported tier falls back to `Scalar` (so `NSCOG_SIMD=avx2` on a
/// non-AVX2 host A/B-benches the scalar path instead of faulting);
/// `None`/auto picks the best supported tier.
fn resolve_tier(request: Option<SimdTier>) -> SimdTier {
    match request {
        Some(t) if t.is_supported() => t,
        Some(_) => SimdTier::Scalar,
        None => *available_tiers().first().unwrap_or(&SimdTier::Scalar),
    }
}

static TIER: OnceLock<SimdTier> = OnceLock::new();

/// The tier every dispatched kernel in this process routes through.
/// Selected once: `NSCOG_SIMD` override (clamped to host support), else
/// the best feature-detected tier. Reading the cached value is one atomic
/// load and never allocates (the one-time selection itself may read the
/// environment; it runs on the first kernel call).
pub fn active_tier() -> SimdTier {
    *TIER.get_or_init(|| {
        resolve_tier(std::env::var("NSCOG_SIMD").ok().as_deref().and_then(parse_tier))
    })
}

// ---------------------------------------------------------------------------
// Scalar tier: the reference kernels (PR 1 Harley–Seal popcount plus
// chunked loops shaped so LLVM's autovectorizer can widen them).
// ---------------------------------------------------------------------------

mod scalar {
    use super::DOT_LANES;

    /// Carry-save adder over three words: (sum, carry) bit-planes.
    #[inline]
    fn csa(a: u64, b: u64, c: u64) -> (u64, u64) {
        let u = a ^ b;
        (u ^ c, (a & b) | (u & c))
    }

    /// Harley–Seal bulk popcount of the XOR of two equal-length word
    /// slices: each 16-word chunk folds through a carry-save adder tree so
    /// only one `count_ones` (weight 16) is paid per chunk, with the
    /// running ones/twos/fours/eights planes and the tail counted once at
    /// the end.
    pub fn xor_hamming(a: &[u64], b: &[u64]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut ones = 0u64;
        let mut twos = 0u64;
        let mut fours = 0u64;
        let mut eights = 0u64;
        let mut sixteens_pop = 0u32;
        let chunks = n / 16;
        for c in 0..chunks {
            let i = c * 16;
            let w = |k: usize| a[i + k] ^ b[i + k];
            let (ones1, twos1) = csa(ones, w(0), w(1));
            let (ones2, twos2) = csa(ones1, w(2), w(3));
            let (twos3, fours1) = csa(twos, twos1, twos2);
            let (ones3, twos4) = csa(ones2, w(4), w(5));
            let (ones4, twos5) = csa(ones3, w(6), w(7));
            let (twos6, fours2) = csa(twos3, twos4, twos5);
            let (fours3, eights1) = csa(fours, fours1, fours2);
            let (ones5, twos7) = csa(ones4, w(8), w(9));
            let (ones6, twos8) = csa(ones5, w(10), w(11));
            let (twos9, fours4) = csa(twos6, twos7, twos8);
            let (ones7, twos10) = csa(ones6, w(12), w(13));
            let (ones8, twos11) = csa(ones7, w(14), w(15));
            let (twos12, fours5) = csa(twos9, twos10, twos11);
            let (fours6, eights2) = csa(fours3, fours4, fours5);
            let (eights3, sixteens) = csa(eights, eights1, eights2);
            ones = ones8;
            twos = twos12;
            fours = fours6;
            eights = eights3;
            sixteens_pop += sixteens.count_ones();
        }
        let mut total = 16 * sixteens_pop
            + 8 * eights.count_ones()
            + 4 * fours.count_ones()
            + 2 * twos.count_ones()
            + ones.count_ones();
        for k in chunks * 16..n {
            total += (a[k] ^ b[k]).count_ones();
        }
        total
    }

    pub fn popcount(a: &[u64]) -> u32 {
        a.iter().map(|w| w.count_ones()).sum()
    }

    /// Query-blocked Hamming: one pass over `row`, accumulating the XOR
    /// popcount against every query in the block (`out[j] +=` style
    /// overwrite). The row word is loaded once per word position and
    /// feeds all accumulators — the memory-bound scan's row fetch is
    /// amortized across the batch. Integer partial sums, so the result
    /// equals per-query [`xor_hamming`] exactly.
    pub fn xor_hamming_block(row: &[u64], queries: &[&[u64]], out: &mut [u32]) {
        debug_assert_eq!(queries.len(), out.len());
        for o in out.iter_mut() {
            *o = 0;
        }
        for (w, &rw) in row.iter().enumerate() {
            for (j, q) in queries.iter().enumerate() {
                out[j] += (rw ^ q[w]).count_ones();
            }
        }
    }

    pub fn xor_into(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, s) in dst.iter_mut().zip(src) {
            *d ^= *s;
        }
    }

    /// One carry-save counter-plane step across a whole word row:
    /// `(plane, carry) ← (plane ^ carry, plane & carry)`. Returns `true`
    /// when the outgoing carry is all-zero (the caller's early exit).
    pub fn csa_step(plane: &mut [u64], carry: &mut [u64]) -> bool {
        debug_assert_eq!(plane.len(), carry.len());
        let mut any = 0u64;
        for (p, c) in plane.iter_mut().zip(carry.iter_mut()) {
            let t = *p & *c;
            *p ^= *c;
            *c = t;
            any |= t;
        }
        any == 0
    }

    /// In-place cyclic funnel shift left by `b` bits (1..=63) over a word
    /// row that has already been word-rotated:
    /// `w[j] ← (w[j] << b) | (w[j-1 mod n] >> (64-b))`, evaluated against
    /// the pre-call values (backward pass, wrap via the saved last word).
    pub fn funnel_shl(words: &mut [u64], b: u32) {
        debug_assert!((1..=63).contains(&b));
        let n = words.len();
        if n == 0 {
            return;
        }
        let last = words[n - 1];
        for j in (1..n).rev() {
            words[j] = (words[j] << b) | (words[j - 1] >> (64 - b));
        }
        words[0] = (words[0] << b) | (last >> (64 - b));
    }

    pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        debug_assert_eq!(out.len(), x.len());
        for (o, &v) in out.iter_mut().zip(x) {
            *o += w * v;
        }
    }

    /// Canonical lane-strided accumulation over a whole number of
    /// [`DOT_LANES`]-element groups (the caller peels to a lane boundary
    /// and handles the tail): element `j` of each group lands in lane `j`.
    pub fn dot_lanes(lanes: &mut [f64; DOT_LANES], a: &[f32], b: &[f32]) {
        debug_assert_eq!(a.len(), b.len());
        debug_assert_eq!(a.len() % DOT_LANES, 0);
        for (ca, cb) in a.chunks_exact(DOT_LANES).zip(b.chunks_exact(DOT_LANES)) {
            for j in 0..DOT_LANES {
                lanes[j] += (ca[j] as f64) * (cb[j] as f64);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 tier (x86_64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::DOT_LANES;
    use std::arch::x86_64::*;

    /// Muła nibble-LUT popcount of one 256-bit lane: per-byte counts via
    /// two `vpshufb` table lookups, summed into 4×u64 by `vpsadbw`.
    /// (`target_feature` carried so the by-value `__m256i` ABI is sound.)
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt256(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi64(acc: __m256i) -> u32 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_hamming(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(c * 4) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(c * 4) as *const __m256i);
            acc = _mm256_add_epi64(acc, popcnt256(_mm256_xor_si256(va, vb)));
        }
        let mut total = hsum_epi64(acc);
        for k in chunks * 4..n {
            total += (a[k] ^ b[k]).count_ones();
        }
        total
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn popcount(a: &[u64]) -> u32 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(c * 4) as *const __m256i);
            acc = _mm256_add_epi64(acc, popcnt256(va));
        }
        let mut total = hsum_epi64(acc);
        for k in chunks * 4..n {
            total += a[k].count_ones();
        }
        total
    }

    /// Query-blocked Hamming (see the scalar tier): each 256-bit row
    /// chunk is loaded once and XOR-popcounted against up to 8 block
    /// queries whose accumulators stay in registers.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_hamming_block(row: &[u64], queries: &[&[u64]], out: &mut [u32]) {
        let m = queries.len();
        debug_assert!(m <= 8);
        let n = row.len();
        let chunks = n / 4;
        let mut accs = [_mm256_setzero_si256(); 8];
        for c in 0..chunks {
            let vr = _mm256_loadu_si256(row.as_ptr().add(c * 4) as *const __m256i);
            for (j, q) in queries.iter().enumerate() {
                let vq = _mm256_loadu_si256(q.as_ptr().add(c * 4) as *const __m256i);
                accs[j] = _mm256_add_epi64(accs[j], popcnt256(_mm256_xor_si256(vr, vq)));
            }
        }
        for (j, q) in queries.iter().enumerate() {
            let mut total = hsum_epi64(accs[j]);
            for k in chunks * 4..n {
                total += (row[k] ^ q[k]).count_ones();
            }
            out[j] = total;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_into(dst: &mut [u64], src: &[u64]) {
        let n = dst.len();
        let chunks = n / 4;
        for c in 0..chunks {
            let pd = dst.as_mut_ptr().add(c * 4);
            let v = _mm256_xor_si256(
                _mm256_loadu_si256(pd as *const __m256i),
                _mm256_loadu_si256(src.as_ptr().add(c * 4) as *const __m256i),
            );
            _mm256_storeu_si256(pd as *mut __m256i, v);
        }
        for k in chunks * 4..n {
            dst[k] ^= src[k];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn csa_step(plane: &mut [u64], carry: &mut [u64]) -> bool {
        let n = plane.len();
        let chunks = n / 4;
        let mut anyv = _mm256_setzero_si256();
        for c in 0..chunks {
            let pp = plane.as_mut_ptr().add(c * 4);
            let pc = carry.as_mut_ptr().add(c * 4);
            let vp = _mm256_loadu_si256(pp as *const __m256i);
            let vc = _mm256_loadu_si256(pc as *const __m256i);
            let t = _mm256_and_si256(vp, vc);
            _mm256_storeu_si256(pp as *mut __m256i, _mm256_xor_si256(vp, vc));
            _mm256_storeu_si256(pc as *mut __m256i, t);
            anyv = _mm256_or_si256(anyv, t);
        }
        let mut tail_any = 0u64;
        for k in chunks * 4..n {
            let t = plane[k] & carry[k];
            plane[k] ^= carry[k];
            carry[k] = t;
            tail_any |= t;
        }
        _mm256_testz_si256(anyv, anyv) == 1 && tail_any == 0
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn funnel_shl(words: &mut [u64], b: u32) {
        let n = words.len();
        if n == 0 {
            return;
        }
        let last = words[n - 1];
        let vb = _mm_cvtsi32_si128(b as i32);
        let vrb = _mm_cvtsi32_si128(64 - b as i32);
        let p = words.as_mut_ptr();
        // Backward over 4-word blocks: block [j-4, j) reads its own old
        // values plus [j-5, j-1), all still unmodified when descending.
        let mut j = n;
        while j >= 5 {
            let cur = _mm256_loadu_si256(p.add(j - 4) as *const __m256i);
            let prev = _mm256_loadu_si256(p.add(j - 5) as *const __m256i);
            let v = _mm256_or_si256(_mm256_sll_epi64(cur, vb), _mm256_srl_epi64(prev, vrb));
            _mm256_storeu_si256(p.add(j - 4) as *mut __m256i, v);
            j -= 4;
        }
        for m in (1..j).rev() {
            words[m] = (words[m] << b) | (words[m - 1] >> (64 - b));
        }
        words[0] = (words[0] << b) | (last >> (64 - b));
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        let n = out.len();
        let chunks = n / 8;
        let vw = _mm256_set1_ps(w);
        for c in 0..chunks {
            let po = out.as_mut_ptr().add(c * 8);
            let vx = _mm256_loadu_ps(x.as_ptr().add(c * 8));
            let vo = _mm256_loadu_ps(po);
            // mul then add (no FMA): matches the scalar tier's two
            // correctly-rounded f32 operations bit-for-bit
            _mm256_storeu_ps(po, _mm256_add_ps(vo, _mm256_mul_ps(vw, vx)));
        }
        for k in chunks * 8..n {
            out[k] += w * x[k];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_lanes(lanes: &mut [f64; DOT_LANES], a: &[f32], b: &[f32]) {
        // caller guarantees a.len() == b.len() and a multiple of 8
        let n = a.len();
        let mut acc_lo = _mm256_loadu_pd(lanes.as_ptr());
        let mut acc_hi = _mm256_loadu_pd(lanes.as_ptr().add(4));
        let mut i = 0;
        while i < n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            let a_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(va));
            let a_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(va));
            let b_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(vb));
            let b_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(vb));
            // mul then add in f64 per lane — the canonical rounding schedule
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(a_lo, b_lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(a_hi, b_hi));
            i += 8;
        }
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
    }
}

// ---------------------------------------------------------------------------
// NEON tier (aarch64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::DOT_LANES;
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn xor_hamming(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len();
        let chunks = n / 2;
        let mut total = 0u32;
        for c in 0..chunks {
            let va = vld1q_u64(a.as_ptr().add(c * 2));
            let vb = vld1q_u64(b.as_ptr().add(c * 2));
            let cnt = vcntq_u8(vreinterpretq_u8_u64(veorq_u64(va, vb)));
            total += vaddlvq_u8(cnt) as u32;
        }
        for k in chunks * 2..n {
            total += (a[k] ^ b[k]).count_ones();
        }
        total
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn popcount(a: &[u64]) -> u32 {
        let n = a.len();
        let chunks = n / 2;
        let mut total = 0u32;
        for c in 0..chunks {
            let va = vld1q_u64(a.as_ptr().add(c * 2));
            total += vaddlvq_u8(vcntq_u8(vreinterpretq_u8_u64(va))) as u32;
        }
        for k in chunks * 2..n {
            total += a[k].count_ones();
        }
        total
    }

    /// Query-blocked Hamming (see the scalar tier): each 128-bit row
    /// chunk is loaded once and popcounted against the whole block.
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_hamming_block(row: &[u64], queries: &[&[u64]], out: &mut [u32]) {
        let m = queries.len();
        debug_assert!(m <= 8);
        let n = row.len();
        let chunks = n / 2;
        let mut accs = [0u32; 8];
        for c in 0..chunks {
            let vr = vld1q_u64(row.as_ptr().add(c * 2));
            for (j, q) in queries.iter().enumerate() {
                let vq = vld1q_u64(q.as_ptr().add(c * 2));
                let cnt = vcntq_u8(vreinterpretq_u8_u64(veorq_u64(vr, vq)));
                accs[j] += vaddlvq_u8(cnt) as u32;
            }
        }
        for (j, q) in queries.iter().enumerate() {
            let mut total = accs[j];
            for k in chunks * 2..n {
                total += (row[k] ^ q[k]).count_ones();
            }
            out[j] = total;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn xor_into(dst: &mut [u64], src: &[u64]) {
        let n = dst.len();
        let chunks = n / 2;
        for c in 0..chunks {
            let pd = dst.as_mut_ptr().add(c * 2);
            let v = veorq_u64(vld1q_u64(pd), vld1q_u64(src.as_ptr().add(c * 2)));
            vst1q_u64(pd, v);
        }
        for k in chunks * 2..n {
            dst[k] ^= src[k];
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn csa_step(plane: &mut [u64], carry: &mut [u64]) -> bool {
        let n = plane.len();
        let chunks = n / 2;
        let mut anyv = vdupq_n_u64(0);
        for c in 0..chunks {
            let pp = plane.as_mut_ptr().add(c * 2);
            let pc = carry.as_mut_ptr().add(c * 2);
            let vp = vld1q_u64(pp);
            let vc = vld1q_u64(pc);
            let t = vandq_u64(vp, vc);
            vst1q_u64(pp, veorq_u64(vp, vc));
            vst1q_u64(pc, t);
            anyv = vorrq_u64(anyv, t);
        }
        let mut tail_any = 0u64;
        for k in chunks * 2..n {
            let t = plane[k] & carry[k];
            plane[k] ^= carry[k];
            carry[k] = t;
            tail_any |= t;
        }
        vmaxvq_u32(vreinterpretq_u32_u64(anyv)) == 0 && tail_any == 0
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn funnel_shl(words: &mut [u64], b: u32) {
        let n = words.len();
        if n == 0 {
            return;
        }
        let last = words[n - 1];
        let vl = vdupq_n_s64(b as i64);
        let vr = vdupq_n_s64(-((64 - b) as i64)); // negative count = shift right
        let p = words.as_mut_ptr();
        let mut j = n;
        while j >= 3 {
            let cur = vld1q_u64(p.add(j - 2) as *const u64);
            let prev = vld1q_u64(p.add(j - 3) as *const u64);
            let v = vorrq_u64(vshlq_u64(cur, vl), vshlq_u64(prev, vr));
            vst1q_u64(p.add(j - 2), v);
            j -= 2;
        }
        for m in (1..j).rev() {
            words[m] = (words[m] << b) | (words[m - 1] >> (64 - b));
        }
        words[0] = (words[0] << b) | (last >> (64 - b));
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        let n = out.len();
        let chunks = n / 4;
        for c in 0..chunks {
            let po = out.as_mut_ptr().add(c * 4);
            let vx = vld1q_f32(x.as_ptr().add(c * 4));
            let vo = vld1q_f32(po as *const f32);
            vst1q_f32(po, vaddq_f32(vo, vmulq_n_f32(vx, w)));
        }
        for k in chunks * 4..n {
            out[k] += w * x[k];
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_lanes(lanes: &mut [f64; DOT_LANES], a: &[f32], b: &[f32]) {
        let n = a.len();
        let mut acc0 = vld1q_f64(lanes.as_ptr());
        let mut acc1 = vld1q_f64(lanes.as_ptr().add(2));
        let mut acc2 = vld1q_f64(lanes.as_ptr().add(4));
        let mut acc3 = vld1q_f64(lanes.as_ptr().add(6));
        let mut i = 0;
        while i < n {
            let a01 = vld1q_f32(a.as_ptr().add(i));
            let a23 = vld1q_f32(a.as_ptr().add(i + 4));
            let b01 = vld1q_f32(b.as_ptr().add(i));
            let b23 = vld1q_f32(b.as_ptr().add(i + 4));
            // mul then add (no fused multiply-add): canonical roundings
            acc0 = vaddq_f64(
                acc0,
                vmulq_f64(vcvt_f64_f32(vget_low_f32(a01)), vcvt_f64_f32(vget_low_f32(b01))),
            );
            acc1 = vaddq_f64(
                acc1,
                vmulq_f64(vcvt_f64_f32(vget_high_f32(a01)), vcvt_f64_f32(vget_high_f32(b01))),
            );
            acc2 = vaddq_f64(
                acc2,
                vmulq_f64(vcvt_f64_f32(vget_low_f32(a23)), vcvt_f64_f32(vget_low_f32(b23))),
            );
            acc3 = vaddq_f64(
                acc3,
                vmulq_f64(vcvt_f64_f32(vget_high_f32(a23)), vcvt_f64_f32(vget_high_f32(b23))),
            );
            i += 8;
        }
        vst1q_f64(lanes.as_mut_ptr(), acc0);
        vst1q_f64(lanes.as_mut_ptr().add(2), acc1);
        vst1q_f64(lanes.as_mut_ptr().add(4), acc2);
        vst1q_f64(lanes.as_mut_ptr().add(6), acc3);
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points. The plain functions route through the cached
// process tier (guaranteed supported by construction); the `_tier`
// variants take an explicit tier for A/B benches and the equivalence
// property tests, falling back to scalar when the tier is not supported
// on this host.
// ---------------------------------------------------------------------------

macro_rules! dispatch {
    ($tier:expr, $scalar:expr, $avx2:expr, $neon:expr) => {
        match $tier {
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => unsafe { $avx2 },
            #[cfg(target_arch = "aarch64")]
            SimdTier::Neon => unsafe { $neon },
            #[allow(unreachable_patterns)]
            _ => $scalar,
        }
    };
}

/// Popcount of `a XOR b` — the Hamming-distance word kernel behind every
/// binary scan, sketch prefix pass, and incremental-bound chunk.
pub fn xor_hamming(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    dispatch!(
        active_tier(),
        scalar::xor_hamming(a, b),
        x86::xor_hamming(a, b),
        neon::xor_hamming(a, b)
    )
}

/// [`xor_hamming`] forced onto one tier (tests / A-B benches).
pub fn xor_hamming_tier(t: SimdTier, a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::xor_hamming(a, b),
        x86::xor_hamming(a, b),
        neon::xor_hamming(a, b)
    )
}

/// Maximum block width [`xor_hamming_block`] accepts — matches the
/// codebook scans' `QUERY_BLOCK` so one item-row load feeds a whole
/// block of query accumulators held in registers.
pub const HAMMING_BLOCK: usize = 8;

/// Query-blocked Hamming: `out[j] = popcount(row XOR queries[j])` in one
/// pass over `row`, so the (memory-bound) row fetch is amortized across
/// the block. At most [`HAMMING_BLOCK`] queries per call; every query
/// must be at least `row.len()` words. Integer partial sums → results
/// are bit-identical to per-query [`xor_hamming`] on every tier.
pub fn xor_hamming_block(row: &[u64], queries: &[&[u64]], out: &mut [u32]) {
    assert!(queries.len() <= HAMMING_BLOCK);
    assert_eq!(queries.len(), out.len());
    dispatch!(
        active_tier(),
        scalar::xor_hamming_block(row, queries, out),
        x86::xor_hamming_block(row, queries, out),
        neon::xor_hamming_block(row, queries, out)
    )
}

/// [`xor_hamming_block`] forced onto one tier (tests / A-B benches).
pub fn xor_hamming_block_tier(t: SimdTier, row: &[u64], queries: &[&[u64]], out: &mut [u32]) {
    assert!(queries.len() <= HAMMING_BLOCK);
    assert_eq!(queries.len(), out.len());
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::xor_hamming_block(row, queries, out),
        x86::xor_hamming_block(row, queries, out),
        neon::xor_hamming_block(row, queries, out)
    )
}

/// Popcount of a word slice (`BinaryHV::popcount`).
pub fn popcount_words(a: &[u64]) -> u32 {
    dispatch!(
        active_tier(),
        scalar::popcount(a),
        x86::popcount(a),
        neon::popcount(a)
    )
}

/// [`popcount_words`] forced onto one tier.
pub fn popcount_words_tier(t: SimdTier, a: &[u64]) -> u32 {
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::popcount(a),
        x86::popcount(a),
        neon::popcount(a)
    )
}

/// `dst ^= src` — the XOR BIND unit.
pub fn xor_into(dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len());
    dispatch!(
        active_tier(),
        scalar::xor_into(dst, src),
        x86::xor_into(dst, src),
        neon::xor_into(dst, src)
    )
}

/// [`xor_into`] forced onto one tier.
pub fn xor_into_tier(t: SimdTier, dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len());
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::xor_into(dst, src),
        x86::xor_into(dst, src),
        neon::xor_into(dst, src)
    )
}

/// One bit-sliced counter-plane update across a word row (the `majority`
/// inner loop): `(plane, carry) ← (plane ^ carry, plane & carry)`.
/// Returns `true` when the outgoing carry is all-zero, letting the caller
/// stop propagating into higher planes.
pub fn csa_step(plane: &mut [u64], carry: &mut [u64]) -> bool {
    debug_assert_eq!(plane.len(), carry.len());
    dispatch!(
        active_tier(),
        scalar::csa_step(plane, carry),
        x86::csa_step(plane, carry),
        neon::csa_step(plane, carry)
    )
}

/// [`csa_step`] forced onto one tier.
pub fn csa_step_tier(t: SimdTier, plane: &mut [u64], carry: &mut [u64]) -> bool {
    debug_assert_eq!(plane.len(), carry.len());
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::csa_step(plane, carry),
        x86::csa_step(plane, carry),
        neon::csa_step(plane, carry)
    )
}

/// In-place cyclic funnel shift left by `b` bits (1..=63) — the bit half
/// of `BinaryHV::permute` after its word rotation:
/// `w[j] ← (w[j] << b) | (w[j-1 mod n] >> (64-b))` against pre-call
/// values.
pub fn funnel_shl(words: &mut [u64], b: u32) {
    debug_assert!((1..=63).contains(&b));
    dispatch!(
        active_tier(),
        scalar::funnel_shl(words, b),
        x86::funnel_shl(words, b),
        neon::funnel_shl(words, b)
    )
}

/// [`funnel_shl`] forced onto one tier.
pub fn funnel_shl_tier(t: SimdTier, words: &mut [u64], b: u32) {
    debug_assert!((1..=63).contains(&b));
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::funnel_shl(words, b),
        x86::funnel_shl(words, b),
        neon::funnel_shl(words, b)
    )
}

/// `out[i] += w * x[i]` — the f32 projection/bundle kernel
/// (`project_signed_into`, `weighted_bundle`, `ops::weighted_sum`).
/// Element-wise, so bit-identical across tiers for free.
pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    dispatch!(
        active_tier(),
        scalar::axpy_f32(out, w, x),
        x86::axpy_f32(out, w, x),
        neon::axpy_f32(out, w, x)
    )
}

/// [`axpy_f32`] forced onto one tier.
pub fn axpy_f32_tier(t: SimdTier, out: &mut [f32], w: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let t = if t.is_supported() { t } else { SimdTier::Scalar };
    dispatch!(
        t,
        scalar::axpy_f32(out, w, x),
        x86::axpy_f32(out, w, x),
        neon::axpy_f32(out, w, x)
    )
}

/// The canonical f32→f64 dot-product accumulator: [`DOT_LANES`]
/// independent f64 lanes, element `p` of the logical row landing in lane
/// `p % DOT_LANES` (tracked by `phase` across chunk splits), reduced
/// left-to-right by [`Self::value`].
///
/// `acc.accumulate(a0, b0); acc.accumulate(a1, b1)` is bit-identical to
/// one `accumulate` over the concatenations for **any** split point —
/// the invariant the bound-pruned real scans rely on to resume a row
/// after the sketch prefix and still hand back scores exactly equal to
/// [`crate::vsa::RealHV::dot`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DotAcc {
    lanes: [f64; DOT_LANES],
    phase: u8,
}

impl Default for DotAcc {
    fn default() -> Self {
        Self::new()
    }
}

impl DotAcc {
    pub fn new() -> DotAcc {
        DotAcc {
            lanes: [0.0; DOT_LANES],
            phase: 0,
        }
    }

    #[inline]
    fn push(&mut self, a: f32, b: f32) {
        self.lanes[self.phase as usize] += (a as f64) * (b as f64);
        self.phase = (self.phase + 1) % DOT_LANES as u8;
    }

    /// Fold `a · b` into the accumulator, continuing the canonical lane
    /// schedule from wherever the previous chunk left off.
    pub fn accumulate(&mut self, a: &[f32], b: &[f32]) {
        self.accumulate_tier(active_tier(), a, b);
    }

    /// [`Self::accumulate`] forced onto one tier (bit-identical result).
    pub fn accumulate_tier(&mut self, t: SimdTier, a: &[f32], b: &[f32]) {
        debug_assert_eq!(a.len(), b.len());
        let t = if t.is_supported() { t } else { SimdTier::Scalar };
        let mut i = 0usize;
        // peel to a lane boundary so the wide main loop starts at lane 0
        while self.phase != 0 && i < a.len() {
            self.push(a[i], b[i]);
            i += 1;
        }
        let main = (a.len() - i) / DOT_LANES * DOT_LANES;
        if main > 0 {
            let (am, bm) = (&a[i..i + main], &b[i..i + main]);
            dispatch!(
                t,
                scalar::dot_lanes(&mut self.lanes, am, bm),
                x86::dot_lanes(&mut self.lanes, am, bm),
                neon::dot_lanes(&mut self.lanes, am, bm)
            );
            i += main;
        }
        while i < a.len() {
            self.push(a[i], b[i]);
            i += 1;
        }
    }

    /// Canonical reduction: lanes summed left-to-right in f64.
    pub fn value(&self) -> f64 {
        let mut s = 0.0;
        for &l in &self.lanes {
            s += l;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_res;
    use crate::util::Rng;

    #[test]
    fn tier_parsing_and_resolution() {
        assert_eq!(parse_tier("scalar"), Some(SimdTier::Scalar));
        assert_eq!(parse_tier(" AVX2 "), Some(SimdTier::Avx2));
        assert_eq!(parse_tier("neon"), Some(SimdTier::Neon));
        assert_eq!(parse_tier("auto"), None);
        assert_eq!(parse_tier("bogus"), None);
        // auto picks the best supported tier; explicit unsupported
        // requests clamp to scalar; explicit scalar always honored
        assert_eq!(resolve_tier(None), available_tiers()[0]);
        assert_eq!(resolve_tier(Some(SimdTier::Scalar)), SimdTier::Scalar);
        for t in [SimdTier::Avx2, SimdTier::Neon] {
            let r = resolve_tier(Some(t));
            assert!(r == t || r == SimdTier::Scalar);
            assert!(r.is_supported());
        }
        assert!(active_tier().is_supported());
        assert!(available_tiers().contains(&SimdTier::Scalar));
        assert_eq!(SimdTier::Scalar.name(), "scalar");
    }

    #[test]
    fn every_supported_tier_matches_scalar_on_word_kernels() {
        forall_res(
            9001,
            40,
            |r| {
                // lengths straddle every tier's vector width and tail path
                let n = r.below(70);
                let a: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
                let b: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
                (a, b)
            },
            |(a, b)| {
                let naive: u32 = a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum();
                for t in available_tiers() {
                    if xor_hamming_tier(t, a, b) != naive {
                        return Err(format!("xor_hamming diverged on {}", t.name()));
                    }
                    if popcount_words_tier(t, a) != a.iter().map(|w| w.count_ones()).sum::<u32>()
                    {
                        return Err(format!("popcount diverged on {}", t.name()));
                    }
                    let mut d = a.clone();
                    xor_into_tier(t, &mut d, b);
                    let want: Vec<u64> = a.iter().zip(b).map(|(x, y)| x ^ y).collect();
                    if d != want {
                        return Err(format!("xor_into diverged on {}", t.name()));
                    }
                }
                // identical rows: hamming must be exactly zero on all tiers
                for t in available_tiers() {
                    if xor_hamming_tier(t, a, a) != 0 {
                        return Err(format!("xor_hamming(a,a) != 0 on {}", t.name()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_supported_tier_matches_per_query_on_blocked_hamming() {
        forall_res(
            9005,
            40,
            |r| {
                // row lengths straddle the vector widths; block sizes
                // cover 1..=HAMMING_BLOCK including ragged last blocks
                let n = r.below(70);
                let m = 1 + r.below(HAMMING_BLOCK);
                let row: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
                let queries: Vec<Vec<u64>> = (0..m)
                    .map(|_| (0..n).map(|_| r.next_u64()).collect())
                    .collect();
                (row, queries)
            },
            |(row, queries)| {
                let qrefs: Vec<&[u64]> = queries.iter().map(|q| q.as_slice()).collect();
                let want: Vec<u32> = queries
                    .iter()
                    .map(|q| row.iter().zip(q).map(|(x, y)| (x ^ y).count_ones()).sum())
                    .collect();
                for t in available_tiers() {
                    let mut out = vec![0u32; queries.len()];
                    xor_hamming_block_tier(t, row, &qrefs, &mut out);
                    if out != want {
                        return Err(format!("xor_hamming_block diverged on {}", t.name()));
                    }
                }
                let mut out = vec![0u32; queries.len()];
                xor_hamming_block(row, &qrefs, &mut out);
                if out != want {
                    return Err("dispatched xor_hamming_block diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_supported_tier_matches_scalar_on_csa_and_funnel() {
        forall_res(
            9002,
            40,
            |r| {
                let n = r.below(40);
                let plane: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
                let carry: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
                let shift = 1 + r.below(63) as u32;
                (plane, carry, shift)
            },
            |(plane, carry, shift)| {
                let (mut p0, mut c0) = (plane.clone(), carry.clone());
                let z0 = scalar::csa_step(&mut p0, &mut c0);
                for t in available_tiers() {
                    let (mut p, mut c) = (plane.clone(), carry.clone());
                    let z = csa_step_tier(t, &mut p, &mut c);
                    if p != p0 || c != c0 || z != z0 {
                        return Err(format!("csa_step diverged on {}", t.name()));
                    }
                    let mut w0 = plane.clone();
                    scalar::funnel_shl(&mut w0, *shift);
                    let mut w = plane.clone();
                    funnel_shl_tier(t, &mut w, *shift);
                    if w != w0 {
                        return Err(format!("funnel_shl diverged on {} b={shift}", t.name()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_supported_tier_matches_scalar_on_f32_kernels_bitwise() {
        forall_res(
            9003,
            40,
            |r| {
                // odd lengths: not multiples of any tier's lane width
                let n = r.below(70);
                let a: Vec<f32> = (0..n).map(|_| r.normal() as f32).collect();
                let b: Vec<f32> = (0..n).map(|_| r.normal() as f32).collect();
                let w = r.normal() as f32;
                // arbitrary split point exercises phase continuation
                let cut = if n > 0 { r.below(n + 1) } else { 0 };
                (a, b, w, cut)
            },
            |(a, b, w, cut)| {
                let mut acc0 = DotAcc::new();
                acc0.accumulate_tier(SimdTier::Scalar, a, b);
                for t in available_tiers() {
                    let mut acc = DotAcc::new();
                    acc.accumulate_tier(t, a, b);
                    if acc != acc0 {
                        return Err(format!("dot lanes diverged on {}", t.name()));
                    }
                    // split at an arbitrary boundary: same lanes, same value
                    let mut split = DotAcc::new();
                    split.accumulate_tier(t, &a[..*cut], &b[..*cut]);
                    split.accumulate_tier(t, &a[*cut..], &b[*cut..]);
                    if split != acc0 {
                        return Err(format!(
                            "chunk-resumed dot diverged on {} cut={cut}",
                            t.name()
                        ));
                    }
                    if split.value().to_bits() != acc0.value().to_bits() {
                        return Err("value() not bit-identical".into());
                    }
                    let mut o0: Vec<f32> = b.clone();
                    scalar::axpy_f32(&mut o0, *w, a);
                    let mut o: Vec<f32> = b.clone();
                    axpy_f32_tier(t, &mut o, *w, a);
                    if o.iter().map(|v| v.to_bits()).ne(o0.iter().map(|v| v.to_bits())) {
                        return Err(format!("axpy diverged on {}", t.name()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dispatched_kernels_agree_with_forced_scalar() {
        // whatever tier this process resolved (including an NSCOG_SIMD
        // override), the dispatched entry points must equal the scalar
        // reference
        let mut r = Rng::new(9004);
        let a: Vec<u64> = (0..37).map(|_| r.next_u64()).collect();
        let b: Vec<u64> = (0..37).map(|_| r.next_u64()).collect();
        assert_eq!(xor_hamming(&a, &b), xor_hamming_tier(SimdTier::Scalar, &a, &b));
        assert_eq!(popcount_words(&a), popcount_words_tier(SimdTier::Scalar, &a));
        let xs: Vec<f32> = (0..53).map(|_| r.normal() as f32).collect();
        let ys: Vec<f32> = (0..53).map(|_| r.normal() as f32).collect();
        let mut d = DotAcc::new();
        d.accumulate(&xs, &ys);
        let mut ds = DotAcc::new();
        ds.accumulate_tier(SimdTier::Scalar, &xs, &ys);
        assert_eq!(d, ds);
        assert_eq!(d.value().to_bits(), ds.value().to_bits());
    }

    #[test]
    fn dot_acc_empty_and_zero_value() {
        let acc = DotAcc::new();
        assert_eq!(acc.value(), 0.0);
        let mut acc = DotAcc::new();
        acc.accumulate(&[], &[]);
        assert_eq!(acc, DotAcc::new());
    }
}
