//! Resonator network (Frady et al. [54]): factorize a composed hypervector
//! `s = a (*) b (*) c ...` into its constituent codebook items by iterated
//! unbind → similarity → weighted-bundle projection → bipolarize.
//!
//! This is the paper's FACT workload and its Resonator-Network kernel
//! (Sec. VI-B): each iteration per factor evaluates
//! `x_hat = s (*) prod(other estimates)`, `n = d(A_i, x_hat)` and
//! `a_new = sign(c(A, n))`.

use super::codebook::RealCodebook;
use super::hypervector::RealHV;
use super::ops;

/// Result of a resonator run.
#[derive(Debug, Clone, PartialEq)]
pub struct ResonatorResult {
    /// Decoded codebook index per factor.
    pub indices: Vec<usize>,
    /// Iterations executed (≤ max_iters).
    pub iterations: usize,
    /// Whether estimates stopped changing before `max_iters`.
    pub converged: bool,
}

/// Resonator network over bipolar codebooks with Hadamard binding.
#[derive(Debug, Clone)]
pub struct Resonator {
    codebooks: Vec<RealCodebook>,
    max_iters: usize,
}

impl Resonator {
    /// `codebooks[f]` holds the candidate items for factor `f`.
    pub fn new(codebooks: Vec<RealCodebook>, max_iters: usize) -> Self {
        assert!(codebooks.len() >= 2, "need at least two factors");
        let d = codebooks[0].dim();
        assert!(codebooks.iter().all(|cb| cb.dim() == d));
        Resonator {
            codebooks,
            max_iters,
        }
    }

    pub fn n_factors(&self) -> usize {
        self.codebooks.len()
    }

    pub fn codebooks(&self) -> &[RealCodebook] {
        &self.codebooks
    }

    /// Initial estimate per factor: bipolarized bundle of the whole
    /// codebook (maximum superposition — no prior).
    pub fn init_estimates(&self) -> Vec<RealHV> {
        self.codebooks
            .iter()
            .map(|cb| {
                let refs: Vec<&RealHV> = cb.items().iter().collect();
                ops::bundle(&refs).sign()
            })
            .collect()
    }

    /// One synchronous sweep: update every factor from the others'
    /// current estimates. Returns scores per factor.
    pub fn sweep(&self, scene: &RealHV, estimates: &mut [RealHV]) -> Vec<Vec<f64>> {
        let f = self.n_factors();
        let mut all_scores = Vec::with_capacity(f);
        let snapshot: Vec<RealHV> = estimates.to_vec();
        for i in 0..f {
            // x_hat = scene (*) prod_{j != i} est_j   (Hadamard unbind)
            let mut x_hat = scene.clone();
            for (j, est) in snapshot.iter().enumerate() {
                if j != i {
                    x_hat = x_hat.bind(est);
                }
            }
            // similarity -> weighted bundle -> sign
            let cb = &self.codebooks[i];
            let scores = cb.scores(&x_hat);
            let weights: Vec<f32> = scores.iter().map(|&s| s as f32).collect();
            let items: Vec<&RealHV> = cb.items().iter().collect();
            estimates[i] = ops::weighted_sum(&weights, &items).sign();
            all_scores.push(scores);
        }
        all_scores
    }

    /// Run to convergence (estimates fixed point) or `max_iters`.
    pub fn factorize(&self, scene: &RealHV) -> ResonatorResult {
        let mut estimates = self.init_estimates();
        let mut converged = false;
        let mut iterations = 0;
        for it in 0..self.max_iters {
            let prev = estimates.clone();
            self.sweep(scene, &mut estimates);
            iterations = it + 1;
            if estimates == prev {
                converged = true;
                break;
            }
        }
        let indices = estimates
            .iter()
            .zip(&self.codebooks)
            .map(|(est, cb)| cb.nearest(est).0)
            .collect();
        ResonatorResult {
            indices,
            iterations,
            converged,
        }
    }

    /// Compose a scene from given item indices (testing / workload gen).
    pub fn compose(&self, indices: &[usize]) -> RealHV {
        assert_eq!(indices.len(), self.n_factors());
        let items: Vec<&RealHV> = indices
            .iter()
            .zip(&self.codebooks)
            .map(|(&i, cb)| cb.item(i))
            .collect();
        ops::bind_all(&items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn make(n_factors: usize, n_items: usize, dim: usize, seed: u64) -> Resonator {
        let mut rng = Rng::new(seed);
        let cbs = (0..n_factors)
            .map(|_| RealCodebook::random_bipolar(&mut rng, n_items, dim))
            .collect();
        Resonator::new(cbs, 60)
    }

    #[test]
    fn factorizes_exact_composition() {
        let r = make(3, 8, 1024, 1);
        let truth = vec![2, 5, 1];
        let scene = r.compose(&truth);
        let out = r.factorize(&scene);
        assert_eq!(out.indices, truth);
        assert!(out.converged, "should converge in 60 iters");
    }

    #[test]
    fn factorizes_many_random_instances() {
        let r = make(3, 10, 2048, 2);
        let mut rng = Rng::new(3);
        let mut correct = 0;
        for _ in 0..10 {
            let truth: Vec<usize> = (0..3).map(|_| rng.below(10)).collect();
            let out = r.factorize(&r.compose(&truth));
            if out.indices == truth {
                correct += 1;
            }
        }
        assert!(correct >= 9, "only {correct}/10 factorizations correct");
    }

    #[test]
    fn two_factor_problem() {
        let r = make(2, 13, 1024, 4);
        let truth = vec![12, 0];
        let out = r.factorize(&r.compose(&truth));
        assert_eq!(out.indices, truth);
    }

    #[test]
    fn four_factor_problem_larger_dim() {
        let r = make(4, 5, 4096, 5);
        let truth = vec![4, 2, 0, 3];
        let out = r.factorize(&r.compose(&truth));
        assert_eq!(out.indices, truth);
    }

    #[test]
    fn noisy_scene_still_factorizes() {
        let r = make(3, 8, 2048, 6);
        let truth = vec![7, 3, 3];
        let mut scene = r.compose(&truth);
        let mut rng = Rng::new(7);
        // flip 10% of signs
        for i in rng.sample_indices(2048, 204) {
            scene.as_mut_slice()[i] = -scene.as_mut_slice()[i];
        }
        let out = r.factorize(&scene);
        assert_eq!(out.indices, truth);
    }

    #[test]
    fn iterations_bounded() {
        let r = make(3, 8, 512, 8);
        let mut rng = Rng::new(9);
        let noise = RealHV::random_bipolar(&mut rng, 512);
        let out = r.factorize(&noise); // garbage input: may not converge
        assert!(out.iterations <= 60);
        assert_eq!(out.indices.len(), 3);
    }
}
