//! Resonator network (Frady et al. [54]): factorize a composed hypervector
//! `s = a (*) b (*) c ...` into its constituent codebook items by iterated
//! unbind → similarity → weighted-bundle projection → bipolarize.
//!
//! This is the paper's FACT workload and its Resonator-Network kernel
//! (Sec. VI-B): each iteration per factor evaluates
//! `x_hat = s (*) prod(other estimates)`, `n = d(A_i, x_hat)` and
//! `a_new = sign(c(A, n))`.

use super::codebook::RealCodebook;
use super::hypervector::{DotAcc, RealHV};
use super::ops;
use super::sketch::{PruneStats, REAL_PRUNE_CHUNK};

/// Result of a resonator run.
#[derive(Debug, Clone, PartialEq)]
pub struct ResonatorResult {
    /// Decoded codebook index per factor.
    pub indices: Vec<usize>,
    /// Iterations executed (≤ max_iters).
    pub iterations: usize,
    /// Whether estimates stopped changing before `max_iters`.
    pub converged: bool,
}

/// Reusable working memory for [`Resonator::sweep_with`] /
/// [`Resonator::factorize_with`]: snapshot of the previous iterate,
/// prefix/suffix bind products, the unbind workspace, and per-factor
/// score buffers. Allocated once (per resonator shape) and reused, so
/// steady-state sweeps perform zero heap allocation.
#[derive(Debug, Clone)]
pub struct ResonatorScratch {
    snapshot: Vec<RealHV>,
    prefix: Vec<RealHV>,
    suffix: Vec<RealHV>,
    x_hat: RealHV,
    scores: Vec<Vec<f64>>,
    /// Reusable buffers for the bound-pruned per-factor index decode at
    /// the end of `factorize_with` (query suffix norms + candidate
    /// order carrying resumable [`DotAcc`] prefix accumulators), plus its
    /// accumulated prune telemetry.
    qnorms: Vec<f64>,
    order: Vec<(f64, DotAcc, u32)>,
    prune: PruneStats,
}

impl ResonatorScratch {
    /// Scores per factor from the most recent sweep.
    pub fn scores(&self) -> &[Vec<f64>] {
        &self.scores
    }

    /// Accumulated pruning telemetry from the factorize decodes run over
    /// this scratch.
    pub fn prune_stats(&self) -> &PruneStats {
        &self.prune
    }
}

/// Resonator network over bipolar codebooks with Hadamard binding.
#[derive(Debug, Clone)]
pub struct Resonator {
    codebooks: Vec<RealCodebook>,
    max_iters: usize,
}

impl Resonator {
    /// `codebooks[f]` holds the candidate items for factor `f`.
    pub fn new(codebooks: Vec<RealCodebook>, max_iters: usize) -> Self {
        assert!(codebooks.len() >= 2, "need at least two factors");
        let d = codebooks[0].dim();
        assert!(codebooks.iter().all(|cb| cb.dim() == d));
        Resonator {
            codebooks,
            max_iters,
        }
    }

    pub fn n_factors(&self) -> usize {
        self.codebooks.len()
    }

    pub fn codebooks(&self) -> &[RealCodebook] {
        &self.codebooks
    }

    /// Initial estimate per factor: bipolarized bundle of the whole
    /// codebook (maximum superposition — no prior).
    pub fn init_estimates(&self) -> Vec<RealHV> {
        self.codebooks
            .iter()
            .map(|cb| {
                let refs: Vec<&RealHV> = cb.items().iter().collect();
                ops::bundle(&refs).sign()
            })
            .collect()
    }

    /// Write the initial estimates into pre-allocated buffers.
    pub fn init_estimates_into(&self, estimates: &mut [RealHV]) {
        assert_eq!(estimates.len(), self.n_factors());
        for (est, cb) in estimates.iter_mut().zip(&self.codebooks) {
            assert_eq!(est.dim(), cb.dim());
            for v in est.as_mut_slice().iter_mut() {
                *v = 0.0;
            }
            for item in cb.items() {
                est.add_assign(item);
            }
            est.sign_assign();
        }
    }

    /// Working buffers sized for this resonator's shape.
    pub fn make_scratch(&self) -> ResonatorScratch {
        let d = self.codebooks[0].dim();
        let f = self.n_factors();
        let max_items = self.codebooks.iter().map(|cb| cb.len()).max().unwrap_or(0);
        ResonatorScratch {
            snapshot: vec![RealHV::zeros(d); f],
            prefix: vec![RealHV::zeros(d); f],
            suffix: vec![RealHV::zeros(d); f],
            x_hat: RealHV::zeros(d),
            scores: self.codebooks.iter().map(|cb| Vec::with_capacity(cb.len())).collect(),
            qnorms: Vec::with_capacity((d + REAL_PRUNE_CHUNK - 1) / REAL_PRUNE_CHUNK),
            order: Vec::with_capacity(max_items),
            prune: PruneStats::default(),
        }
    }

    /// One synchronous sweep: update every factor from the others'
    /// current estimates. Returns scores per factor.
    ///
    /// Convenience wrapper over [`Self::sweep_with`]; hot loops should
    /// hold a [`ResonatorScratch`] and call `sweep_with` directly.
    pub fn sweep(&self, scene: &RealHV, estimates: &mut [RealHV]) -> Vec<Vec<f64>> {
        let mut scratch = self.make_scratch();
        self.sweep_with(scene, estimates, &mut scratch);
        scratch.scores
    }

    /// One synchronous sweep using caller-held working memory — the
    /// steady-state form performs no heap allocation.
    ///
    /// Per-factor unbinding uses prefix/suffix bind products over the
    /// snapshot (`prefix[i] = scene ⊗ est_0 ⊗ … ⊗ est_{i−1}`,
    /// `suffix[i] = est_{i+1} ⊗ … ⊗ est_{F−1}`), so a sweep costs
    /// 3F−4 binds instead of the F(F−1) of the naive per-factor chain,
    /// and the projection runs fused (score → weighted sum → sign) via
    /// [`RealCodebook::project_signed_into`]. Scores land in
    /// `scratch.scores()`.
    pub fn sweep_with(
        &self,
        scene: &RealHV,
        estimates: &mut [RealHV],
        scratch: &mut ResonatorScratch,
    ) {
        let f = self.n_factors();
        assert_eq!(estimates.len(), f);
        for (snap, est) in scratch.snapshot.iter_mut().zip(estimates.iter()) {
            snap.copy_from(est);
        }
        // prefix[i] = scene ⊗ snap_0 ⊗ … ⊗ snap_{i-1}
        scratch.prefix[0].copy_from(scene);
        for i in 1..f {
            let (done, rest) = scratch.prefix.split_at_mut(i);
            rest[0].copy_from(&done[i - 1]);
            rest[0].bind_assign(&scratch.snapshot[i - 1]);
        }
        // suffix[i] = snap_{i+1} ⊗ … ⊗ snap_{F-1}; suffix[F-1] is the
        // empty product and never read.
        if f >= 2 {
            scratch.suffix[f - 2].copy_from(&scratch.snapshot[f - 1]);
            for i in (0..f - 2).rev() {
                let (head, tail) = scratch.suffix.split_at_mut(i + 1);
                head[i].copy_from(&tail[0]);
                head[i].bind_assign(&scratch.snapshot[i + 1]);
            }
        }
        for i in 0..f {
            // x_hat = scene ⊗ prod_{j != i} snap_j  (Hadamard unbind)
            scratch.x_hat.copy_from(&scratch.prefix[i]);
            if i + 1 < f {
                scratch.x_hat.bind_assign(&scratch.suffix[i]);
            }
            self.codebooks[i].project_signed_into(
                &scratch.x_hat,
                &mut scratch.scores[i],
                &mut estimates[i],
            );
        }
    }

    /// Run to convergence (estimates fixed point) or `max_iters`.
    pub fn factorize(&self, scene: &RealHV) -> ResonatorResult {
        let mut scratch = self.make_scratch();
        let mut estimates = self.init_estimates();
        self.factorize_with(scene, &mut estimates, &mut scratch)
    }

    /// [`Self::factorize`] over caller-held buffers: `estimates` must
    /// already hold the starting point (e.g. [`Self::init_estimates_into`]),
    /// and `scratch` is reused across sweeps, so the iteration loop
    /// allocates nothing — the pre-sweep snapshot doubles as the
    /// previous iterate for the convergence check.
    pub fn factorize_with(
        &self,
        scene: &RealHV,
        estimates: &mut [RealHV],
        scratch: &mut ResonatorScratch,
    ) -> ResonatorResult {
        let mut converged = false;
        let mut iterations = 0;
        for it in 0..self.max_iters {
            self.sweep_with(scene, estimates, scratch);
            iterations = it + 1;
            if *estimates == scratch.snapshot[..] {
                converged = true;
                break;
            }
        }
        // decode each factor through the bound-pruned nearest scan
        // (bit-identical to `cb.nearest`, property-tested) over the
        // scratch's reusable buffers, keeping this loop allocation-free
        let indices = estimates
            .iter()
            .zip(&self.codebooks)
            .map(|(est, cb)| {
                cb.nearest_pruned_with_bufs(
                    est,
                    &mut scratch.prune,
                    &mut scratch.qnorms,
                    &mut scratch.order,
                )
                .0
            })
            .collect();
        ResonatorResult {
            indices,
            iterations,
            converged,
        }
    }

    /// Factorize a coalesced batch of scenes over one set of caller-held
    /// buffers: estimates are re-initialized per scene and `scratch` is
    /// reused throughout, so the whole batch allocates only the per-result
    /// index vectors. Result `i` equals `factorize(&scenes[i])` — the
    /// micro-batcher in [`crate::serve`] relies on this equivalence.
    pub fn factorize_batch_with(
        &self,
        scenes: &[RealHV],
        estimates: &mut [RealHV],
        scratch: &mut ResonatorScratch,
    ) -> Vec<ResonatorResult> {
        scenes
            .iter()
            .map(|scene| {
                self.init_estimates_into(estimates);
                self.factorize_with(scene, estimates, scratch)
            })
            .collect()
    }

    /// Compose a scene from given item indices (testing / workload gen).
    pub fn compose(&self, indices: &[usize]) -> RealHV {
        assert_eq!(indices.len(), self.n_factors());
        let items: Vec<&RealHV> = indices
            .iter()
            .zip(&self.codebooks)
            .map(|(&i, cb)| cb.item(i))
            .collect();
        ops::bind_all(&items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn make(n_factors: usize, n_items: usize, dim: usize, seed: u64) -> Resonator {
        let mut rng = Rng::new(seed);
        let cbs = (0..n_factors)
            .map(|_| RealCodebook::random_bipolar(&mut rng, n_items, dim))
            .collect();
        Resonator::new(cbs, 60)
    }

    #[test]
    fn factorizes_exact_composition() {
        let r = make(3, 8, 1024, 1);
        let truth = vec![2, 5, 1];
        let scene = r.compose(&truth);
        let out = r.factorize(&scene);
        assert_eq!(out.indices, truth);
        assert!(out.converged, "should converge in 60 iters");
    }

    #[test]
    fn factorizes_many_random_instances() {
        let r = make(3, 10, 2048, 2);
        let mut rng = Rng::new(3);
        let mut correct = 0;
        for _ in 0..10 {
            let truth: Vec<usize> = (0..3).map(|_| rng.below(10)).collect();
            let out = r.factorize(&r.compose(&truth));
            if out.indices == truth {
                correct += 1;
            }
        }
        assert!(correct >= 9, "only {correct}/10 factorizations correct");
    }

    #[test]
    fn two_factor_problem() {
        let r = make(2, 13, 1024, 4);
        let truth = vec![12, 0];
        let out = r.factorize(&r.compose(&truth));
        assert_eq!(out.indices, truth);
    }

    #[test]
    fn four_factor_problem_larger_dim() {
        let r = make(4, 5, 4096, 5);
        let truth = vec![4, 2, 0, 3];
        let out = r.factorize(&r.compose(&truth));
        assert_eq!(out.indices, truth);
    }

    #[test]
    fn noisy_scene_still_factorizes() {
        let r = make(3, 8, 2048, 6);
        let truth = vec![7, 3, 3];
        let mut scene = r.compose(&truth);
        let mut rng = Rng::new(7);
        // flip 10% of signs
        for i in rng.sample_indices(2048, 204) {
            scene.as_mut_slice()[i] = -scene.as_mut_slice()[i];
        }
        let out = r.factorize(&scene);
        assert_eq!(out.indices, truth);
    }

    /// The pre-optimization sweep (clone-per-factor unbind chain and
    /// unfused score → weights → weighted_sum → sign), kept as the
    /// equivalence oracle for the prefix/suffix + fused implementation.
    fn naive_sweep(r: &Resonator, scene: &RealHV, estimates: &mut [RealHV]) -> Vec<Vec<f64>> {
        let f = r.n_factors();
        let snapshot: Vec<RealHV> = estimates.to_vec();
        let mut all_scores = Vec::with_capacity(f);
        for i in 0..f {
            let mut x_hat = scene.clone();
            for (j, est) in snapshot.iter().enumerate() {
                if j != i {
                    x_hat = x_hat.bind(est);
                }
            }
            let cb = &r.codebooks()[i];
            let scores = cb.scores(&x_hat);
            let weights: Vec<f32> = scores.iter().map(|&s| s as f32).collect();
            let items: Vec<&RealHV> = cb.items().iter().collect();
            estimates[i] = ops::weighted_sum(&weights, &items).sign();
            all_scores.push(scores);
        }
        all_scores
    }

    #[test]
    fn sweep_matches_naive_reference() {
        // Bipolar scenes keep every product exactly ±1, so the optimized
        // sweep must agree bit-for-bit with the naive chain.
        for (factors, seed) in [(2usize, 10u64), (3, 11), (4, 12)] {
            let r = make(factors, 7, 512, seed);
            let mut rng = Rng::new(seed + 100);
            let truth: Vec<usize> = (0..factors).map(|_| rng.below(7)).collect();
            let scene = r.compose(&truth);
            let mut est_fast = r.init_estimates();
            let mut est_naive = est_fast.clone();
            let mut scratch = r.make_scratch();
            for sweep_no in 0..3 {
                r.sweep_with(&scene, &mut est_fast, &mut scratch);
                let naive_scores = naive_sweep(&r, &scene, &mut est_naive);
                assert_eq!(est_fast, est_naive, "factors={factors} sweep={sweep_no}");
                assert_eq!(scratch.scores(), &naive_scores[..], "factors={factors}");
            }
        }
    }

    #[test]
    fn factorize_with_reused_buffers_matches_fresh() {
        // Scratch reuse across scenes must be invisible: identical results
        // to a fresh factorize every time (correct-decode rate itself is
        // covered by factorizes_many_random_instances).
        let r = make(3, 9, 2048, 13);
        let mut scratch = r.make_scratch();
        let mut estimates = r.init_estimates();
        let mut rng = Rng::new(14);
        let mut correct = 0;
        for _ in 0..5 {
            let truth: Vec<usize> = (0..3).map(|_| rng.below(9)).collect();
            let scene = r.compose(&truth);
            r.init_estimates_into(&mut estimates);
            let reused = r.factorize_with(&scene, &mut estimates, &mut scratch);
            assert_eq!(reused, r.factorize(&scene));
            if reused.indices == truth {
                correct += 1;
            }
        }
        assert!(correct >= 4, "only {correct}/5 reused factorizations correct");
        // the pruned per-factor decodes accumulated telemetry: 5 reused
        // runs x 3 factors x 9 items each
        assert_eq!(scratch.prune_stats().items, 5 * 3 * 9);
    }

    #[test]
    fn factorize_batch_matches_per_scene_factorize() {
        let r = make(3, 8, 1024, 16);
        let mut rng = Rng::new(17);
        let scenes: Vec<RealHV> = (0..4)
            .map(|_| {
                let truth: Vec<usize> = (0..3).map(|_| rng.below(8)).collect();
                r.compose(&truth)
            })
            .collect();
        let mut scratch = r.make_scratch();
        let mut estimates = r.init_estimates();
        let batch = r.factorize_batch_with(&scenes, &mut estimates, &mut scratch);
        assert_eq!(batch.len(), scenes.len());
        for (i, scene) in scenes.iter().enumerate() {
            assert_eq!(batch[i], r.factorize(scene), "scene {i}");
        }
    }

    #[test]
    fn init_estimates_into_matches_allocating_init() {
        let r = make(3, 8, 512, 15);
        let mut buf = vec![RealHV::zeros(512); 3];
        r.init_estimates_into(&mut buf);
        assert_eq!(buf, r.init_estimates());
    }

    #[test]
    fn iterations_bounded() {
        let r = make(3, 8, 512, 8);
        let mut rng = Rng::new(9);
        let noise = RealHV::random_bipolar(&mut rng, 512);
        let out = r.factorize(&noise); // garbage input: may not converge
        assert!(out.iterations <= 60);
        assert_eq!(out.indices.len(), 3);
    }
}
