//! CA-90 cellular-automaton codebook regeneration (Kleyko et al. [60]).
//!
//! The accelerator's MCG subsystem stores only a *seed fold* per item
//! vector in SRAM and expands further folds on-the-fly with rule-90:
//! `next[i] = cell[i-1] XOR cell[i+1]` on a cyclic lattice.  This trades
//! SRAM capacity for XOR/shift logic — the paper's "compressed storage of
//! symbols" feature (Tab. V, Recommendation 3).

use super::hypervector::BinaryHV;

/// One rule-90 step on a cyclic bit lattice, written into a caller-held
/// buffer (`src` and `dst` must be disjoint): the streaming core every
/// allocating wrapper and the fused codebook/sketch builds share.
///
/// `next = rotl1(state) XOR rotr1(state)` over the whole `dim`-bit ring.
pub fn ca90_step_into(src: &[u64], dst: &mut [u64], dim: usize) {
    debug_assert_eq!(dim % 64, 0);
    debug_assert_eq!(src.len(), dim / 64);
    debug_assert_eq!(dst.len(), src.len());
    let n = src.len();
    for i in 0..n {
        // left neighbor of bit b is bit b-1 (cyclic); rotating the whole
        // ring left by one gives the "right neighbor" view and vice versa.
        let prev = src[(i + n - 1) % n];
        let next = src[(i + 1) % n];
        let left = (src[i] << 1) | (prev >> 63); // bit b-1 at position b
        let right = (src[i] >> 1) | (next << 63); // bit b+1 at position b
        dst[i] = left ^ right;
    }
}

/// One rule-90 step, allocating convenience over [`ca90_step_into`].
pub fn ca90_step(words: &[u64], dim: usize) -> Vec<u64> {
    let mut out = vec![0u64; words.len()];
    ca90_step_into(words, &mut out, dim);
    out
}

/// Expand fold `k` of an item vector from its seed fold: `k` applications
/// of rule-90.  Fold 0 is the seed itself. Uses one ping-pong scratch
/// pair instead of allocating per generation.
pub fn expand_fold(seed: &[u64], fold_bits: usize, k: usize) -> Vec<u64> {
    let mut state = seed.to_vec();
    let mut next = vec![0u64; seed.len()];
    for _ in 0..k {
        ca90_step_into(&state, &mut next, fold_bits);
        std::mem::swap(&mut state, &mut next);
    }
    state
}

/// Materialize a full `dim`-bit hypervector from a `fold_bits`-bit seed by
/// concatenating CA-90 generations (the paper's extended-dimension
/// mechanism). Generations are streamed fold-by-fold straight into the
/// output words — each step reads the previous fold's slice and writes
/// the next in place, with **zero** intermediate allocations (the fused
/// codebook-build path; see [`crate::vsa::BinaryCodebook::from_seeds`]).
pub fn expand_vector(seed: &[u64], fold_bits: usize, dim: usize) -> BinaryHV {
    let mut words = vec![0u64; dim / 64];
    expand_into(seed, fold_bits, &mut words);
    BinaryHV::from_words(dim, words)
}

/// [`expand_vector`] into a caller-held word buffer (`out.len() · 64`
/// bits), so a scan loop can rematerialize rows one at a time through a
/// single reused scratch row with zero per-item allocation — the
/// seeds-only storage mode's exhaustive-scan core.
pub fn expand_into(seed: &[u64], fold_bits: usize, out: &mut [u64]) {
    let dim = out.len() * 64;
    assert_eq!(dim % fold_bits, 0);
    assert_eq!(fold_bits % 64, 0);
    let fw = fold_bits / 64;
    assert_eq!(seed.len(), fw);
    let n_folds = dim / fold_bits;
    out[..fw].copy_from_slice(seed);
    for k in 1..n_folds {
        let (prev, rest) = out.split_at_mut(k * fw);
        ca90_step_into(&prev[(k - 1) * fw..], &mut rest[..fw], fold_bits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;

    fn naive_step(bits: &[bool]) -> Vec<bool> {
        let n = bits.len();
        (0..n)
            .map(|i| bits[(i + n - 1) % n] ^ bits[(i + 1) % n])
            .collect()
    }

    #[test]
    fn matches_naive_rule90() {
        forall(200, 25, |r| {
            let words: Vec<u64> = (0..2).map(|_| r.next_u64()).collect();
            words
        }, |words| {
            let dim = 128;
            let fast = ca90_step(words, dim);
            let bits: Vec<bool> =
                (0..dim).map(|i| (words[i / 64] >> (i % 64)) & 1 == 1).collect();
            let naive = naive_step(&bits);
            (0..dim).all(|i| ((fast[i / 64] >> (i % 64)) & 1 == 1) == naive[i])
        });
    }

    #[test]
    fn zero_state_is_fixed_point() {
        let z = vec![0u64; 8];
        assert_eq!(ca90_step(&z, 512), z);
    }

    #[test]
    fn expansion_preserves_randomness_quality() {
        // Expanded folds stay quasi-orthogonal to the seed fold.
        let mut rng = Rng::new(1);
        let seed: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        let f1 = expand_fold(&seed, 512, 1);
        let f4 = expand_fold(&seed, 512, 4);
        let ham1: u32 = seed.iter().zip(&f1).map(|(a, b)| (a ^ b).count_ones()).sum();
        let ham4: u32 = seed.iter().zip(&f4).map(|(a, b)| (a ^ b).count_ones()).sum();
        for h in [ham1, ham4] {
            assert!((150..370).contains(&h), "hamming {h} not random-like");
        }
    }

    #[test]
    fn expand_vector_fold0_is_seed() {
        let mut rng = Rng::new(2);
        let seed: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        let hv = expand_vector(&seed, 512, 2048);
        assert_eq!(&hv.words()[..8], &seed[..]);
        assert_eq!(hv.dim(), 2048);
    }

    #[test]
    fn expand_vector_folds_chain() {
        let mut rng = Rng::new(3);
        let seed: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        let hv = expand_vector(&seed, 512, 2048);
        let f2 = expand_fold(&seed, 512, 2);
        assert_eq!(&hv.words()[16..24], &f2[..]);
    }

    #[test]
    fn expand_into_matches_expand_vector() {
        let mut rng = Rng::new(4);
        let seed: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        let hv = expand_vector(&seed, 512, 4096);
        let mut buf = vec![0u64; 4096 / 64];
        expand_into(&seed, 512, &mut buf);
        assert_eq!(hv.words(), &buf[..]);
        // reuse the same buffer for a second item: fully overwritten
        let seed2: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        expand_into(&seed2, 512, &mut buf);
        assert_eq!(expand_vector(&seed2, 512, 4096).words(), &buf[..]);
    }

    #[test]
    fn deterministic_expansion() {
        let seed = vec![0xDEADBEEFCAFEBABEu64; 8];
        let a = expand_vector(&seed, 512, 4096);
        let b = expand_vector(&seed, 512, 4096);
        assert_eq!(a, b);
    }
}
