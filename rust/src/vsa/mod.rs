//! Vector-Symbolic Architecture substrate (paper Sec. VI-A).
//!
//! Two hypervector families cover every workload in the paper:
//!
//! - [`BinaryHV`]: dense binary hypervectors, bit-packed into `u64` words.
//!   Binding = XOR, bundling = integer majority, similarity = Hamming-based
//!   dot product via POPCNT — exactly the arithmetic the paper's VSA
//!   accelerator implements in its BIND/BND/POPCNT units, so the functional
//!   simulator ([`crate::accel`]) is validated against these ops.
//! - [`RealHV`]: real-valued (bipolar f32) hypervectors with Hadamard or
//!   circular-convolution (HRR/NVSA) binding — the representation the L1
//!   Pallas kernels compute on.
//!
//! On top of both: item-memory codebooks with CA-90 on-the-fly
//! regeneration ([`ca90`]), cleanup/associative memory ([`cleanup`]), and
//! the resonator-network factorizer ([`resonator`]). Every word-level hot
//! loop under all of them dispatches once into the runtime-selected SIMD
//! backend ([`kernels`]: AVX2 / NEON / scalar, `NSCOG_SIMD` override) at
//! bit-identical results.

pub mod ca90;
pub mod cleanup;
pub mod codebook;
pub mod hypervector;
pub mod kernels;
pub mod ops;
pub mod resonator;
pub mod sketch;

pub use cleanup::CleanupMemory;
pub use codebook::{BinaryCodebook, RealCodebook};
pub use hypervector::{BinaryHV, RealHV};
pub use kernels::{DotAcc, SimdTier};
pub use resonator::{Resonator, ResonatorResult, ResonatorScratch};
pub use sketch::{BinarySketch, PruneStats, RealSketch};
