//! Cleanup / associative memory: map noisy hypervectors back to the
//! nearest stored prototype (the paper's clean-up memory search, used by
//! the REACT workload's motor-value decoding).

use super::codebook::{BinaryCodebook, RealCodebook};
use super::hypervector::{BinaryHV, RealHV};
use super::sketch::PruneStats;

/// Cleanup memory over binary item vectors.
#[derive(Debug, Clone)]
pub struct CleanupMemory {
    codebook: BinaryCodebook,
}

impl CleanupMemory {
    pub fn new(codebook: BinaryCodebook) -> Self {
        CleanupMemory { codebook }
    }

    pub fn len(&self) -> usize {
        self.codebook.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codebook.is_empty()
    }

    pub fn codebook(&self) -> &BinaryCodebook {
        &self.codebook
    }

    /// Recall the nearest stored item; returns (index, normalized score).
    pub fn recall(&self, query: &BinaryHV) -> (usize, f64) {
        let (idx, score) = self.codebook.nearest(query);
        (idx, score as f64 / self.codebook.dim() as f64)
    }

    /// Recall with a confidence threshold; `None` if the best match is
    /// weaker than `min_cosine` (query too noisy / novel).
    pub fn recall_thresholded(&self, query: &BinaryHV, min_cosine: f64) -> Option<(usize, f64)> {
        let (idx, cos) = self.recall(query);
        (cos >= min_cosine).then_some((idx, cos))
    }

    /// Batched recall through the bound-pruned codebook scan (and, under
    /// `NSCOG_THREADS`, parallel workers) — the REACT recall loop's hot
    /// path. Result `q` equals `recall(&queries[q])` bit-for-bit; most
    /// item rows are only partially streamed (see
    /// [`crate::vsa::sketch`]).
    pub fn recall_batch(&self, queries: &[BinaryHV]) -> Vec<(usize, f64)> {
        self.recall_batch_with(queries, crate::util::parallel::configured_threads())
    }

    /// [`Self::recall_batch`] with an explicit worker count (the serving
    /// engine pins this per worker instead of reading the environment).
    pub fn recall_batch_with(&self, queries: &[BinaryHV], threads: usize) -> Vec<(usize, f64)> {
        self.recall_batch_stats(queries, threads).0
    }

    /// [`Self::recall_batch_with`] plus the scan's [`PruneStats`].
    pub fn recall_batch_stats(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, f64)>, PruneStats) {
        let d = self.codebook.dim() as f64;
        let (best, stats) = self.codebook.nearest_batch_pruned_with(queries, threads);
        (
            best.into_iter()
                .map(|(idx, score)| (idx, score as f64 / d))
                .collect(),
            stats,
        )
    }

    /// Top-`k` recall: the `k` nearest stored items with normalized
    /// scores, ordered by (score desc, index asc) — the sequential oracle
    /// for the sharded top-k merge in [`crate::serve::shard`]. Routed
    /// through the bound-pruned scan, which is property-tested
    /// bit-identical to [`BinaryCodebook::top_k`].
    pub fn recall_topk(&self, query: &BinaryHV, k: usize) -> Vec<(usize, f64)> {
        let d = self.codebook.dim() as f64;
        let mut stats = PruneStats::default();
        self.codebook
            .top_k_pruned(query, k, &mut stats)
            .into_iter()
            .map(|(idx, score)| (idx, score as f64 / d))
            .collect()
    }
}

/// Cleanup memory over real-valued prototypes.
#[derive(Debug, Clone)]
pub struct RealCleanupMemory {
    codebook: RealCodebook,
}

impl RealCleanupMemory {
    pub fn new(codebook: RealCodebook) -> Self {
        RealCleanupMemory { codebook }
    }

    pub fn codebook(&self) -> &RealCodebook {
        &self.codebook
    }

    /// Recall nearest prototype by cosine similarity.
    pub fn recall(&self, query: &RealHV) -> (usize, f64) {
        let mut best = (0usize, f64::NEG_INFINITY);
        for (i, it) in self.codebook.items().iter().enumerate() {
            let c = it.cosine(query);
            if c > best.1 {
                best = (i, c);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::vsa::hypervector::BinaryHV;

    fn flip_bits(hv: &BinaryHV, frac: f64, rng: &mut Rng) -> BinaryHV {
        let mut out = hv.clone();
        let n = (hv.dim() as f64 * frac) as usize;
        for i in rng.sample_indices(hv.dim(), n) {
            out.set(i, !out.get(i));
        }
        out
    }

    #[test]
    fn recalls_exact_member() {
        let mut rng = Rng::new(1);
        let cm = CleanupMemory::new(BinaryCodebook::random(&mut rng, 55, 2048));
        for i in [0usize, 27, 54] {
            let (idx, cos) = cm.recall(cm.codebook().item(i));
            assert_eq!(idx, i);
            assert!((cos - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn recalls_under_noise() {
        let mut rng = Rng::new(2);
        let cm = CleanupMemory::new(BinaryCodebook::random(&mut rng, 55, 2048));
        // up to 30% flipped bits still recalls correctly w.h.p.
        for i in 0..10 {
            let noisy = flip_bits(cm.codebook().item(i), 0.30, &mut rng);
            let (idx, _) = cm.recall(&noisy);
            assert_eq!(idx, i, "item {i} lost under 30% noise");
        }
    }

    #[test]
    fn threshold_rejects_novel_query() {
        let mut rng = Rng::new(3);
        let cm = CleanupMemory::new(BinaryCodebook::random(&mut rng, 16, 2048));
        let novel = BinaryHV::random(&mut rng, 2048);
        assert!(cm.recall_thresholded(&novel, 0.5).is_none());
        assert!(cm
            .recall_thresholded(cm.codebook().item(3), 0.5)
            .is_some());
    }

    #[test]
    fn batched_recall_matches_single() {
        let mut rng = Rng::new(5);
        let cm = CleanupMemory::new(BinaryCodebook::random(&mut rng, 40, 2048));
        let queries: Vec<BinaryHV> = (0..17)
            .map(|i| flip_bits(cm.codebook().item(i % 40), 0.2, &mut rng))
            .collect();
        let batch = cm.recall_batch(&queries);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(batch[q], cm.recall(query), "query {q}");
        }
    }

    #[test]
    fn topk_recall_heads_with_recall_result() {
        let mut rng = Rng::new(6);
        let cm = CleanupMemory::new(BinaryCodebook::random(&mut rng, 30, 2048));
        for i in 0..5 {
            let noisy = flip_bits(cm.codebook().item(i), 0.25, &mut rng);
            let top = cm.recall_topk(&noisy, 4);
            assert_eq!(top.len(), 4);
            assert_eq!(top[0], cm.recall(&noisy), "query {i}");
            for w in top.windows(2) {
                assert!(w[0].1 >= w[1].1, "top-k not score-sorted");
            }
        }
    }

    #[test]
    fn recall_batch_stats_reports_pruning_on_noisy_members() {
        let mut rng = Rng::new(7);
        let cm = CleanupMemory::new(BinaryCodebook::random(&mut rng, 48, 4096));
        let queries: Vec<BinaryHV> = (0..12)
            .map(|i| flip_bits(cm.codebook().item(i % 48), 0.2, &mut rng))
            .collect();
        let (batch, stats) = cm.recall_batch_stats(&queries, 1);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(batch[q], cm.recall(query), "query {q}");
        }
        assert_eq!(stats.items, 12 * 48);
        assert!(
            stats.words_streamed < stats.words_total,
            "noisy-member recalls must prune: {stats:?}"
        );
    }

    #[test]
    fn real_cleanup_recall() {
        let mut rng = Rng::new(4);
        let cm = RealCleanupMemory::new(RealCodebook::random_bipolar(&mut rng, 20, 1024));
        let (idx, cos) = cm.recall(cm.codebook().item(11));
        assert_eq!(idx, 11);
        assert!((cos - 1.0).abs() < 1e-6);
    }
}
