//! Item-memory codebooks: arrays of atomic hypervectors used for symbolic
//! encoding, with optional CA-90 compressed storage.

use super::ca90;
use super::hypervector::{BinaryHV, RealHV, FOLD_BITS, FOLD_WORDS};
use crate::util::{parallel, Rng};

/// Queries per block in the batched scans: each item row is streamed from
/// memory once per block while the block's queries stay cache-resident,
/// so item-memory traffic drops by ~QUERY_BLOCK× versus per-query scans.
const QUERY_BLOCK: usize = 8;

/// A codebook of binary item vectors.
#[derive(Debug, Clone)]
pub struct BinaryCodebook {
    dim: usize,
    items: Vec<BinaryHV>,
}

impl BinaryCodebook {
    /// Generate `n` random item vectors of dimension `dim`.
    pub fn random(rng: &mut Rng, n: usize, dim: usize) -> Self {
        BinaryCodebook {
            dim,
            items: (0..n).map(|_| BinaryHV::random(rng, dim)).collect(),
        }
    }

    /// Reconstruct a full codebook from per-item 512-bit seed folds via
    /// CA-90 expansion (the accelerator's compressed storage scheme).
    pub fn from_seeds(seeds: &[Vec<u64>], dim: usize) -> Self {
        BinaryCodebook {
            dim,
            items: seeds
                .iter()
                .map(|s| ca90::expand_vector(s, FOLD_BITS, dim))
                .collect(),
        }
    }

    /// Build a codebook from pre-generated items, all of dimension `dim`
    /// (e.g. a contiguous slice of another codebook when sharding).
    pub fn from_items(dim: usize, items: Vec<BinaryHV>) -> Self {
        for it in &items {
            assert_eq!(it.dim(), dim);
        }
        BinaryCodebook { dim, items }
    }

    /// Extract seed folds (fold 0 of each item) for compressed storage.
    pub fn seeds(&self) -> Vec<Vec<u64>> {
        self.items
            .iter()
            .map(|hv| hv.words()[..FOLD_WORDS.min(hv.words().len())].to_vec())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn item(&self, i: usize) -> &BinaryHV {
        &self.items[i]
    }

    pub fn items(&self) -> &[BinaryHV] {
        &self.items
    }

    /// Dot-product scores of `query` against every item.
    pub fn scores(&self, query: &BinaryHV) -> Vec<i64> {
        self.items.iter().map(|it| it.dot(query)).collect()
    }

    /// Nearest item index and its score (paper's e(y) = argmax d).
    pub fn nearest(&self, query: &BinaryHV) -> (usize, i64) {
        let mut best = (0usize, i64::MIN);
        for (i, it) in self.items.iter().enumerate() {
            let s = it.dot(query);
            if s > best.1 {
                best = (i, s);
            }
        }
        best
    }

    /// Top-`k` items by score, ordered by (score desc, index asc) — the
    /// total order every sharded/merged scan in [`crate::serve`] must
    /// reproduce, so `top_k(k')[..k]` is prefix-stable for any `k' ≥ k`
    /// and per-shard top-k lists merge into exactly this list.
    pub fn top_k(&self, query: &BinaryHV, k: usize) -> Vec<(usize, i64)> {
        assert_eq!(query.dim(), self.dim);
        let mut top: Vec<(usize, i64)> = Vec::with_capacity(k + 1);
        if k == 0 {
            return top;
        }
        for (i, it) in self.items.iter().enumerate() {
            let s = it.dot_bulk(query);
            // equal scores keep the earlier (smaller) index, matching
            // `nearest`'s first-wins tie rule
            if top.len() == k && s <= top[k - 1].1 {
                continue;
            }
            let pos = top.partition_point(|&(_, ts)| ts >= s);
            top.insert(pos, (i, s));
            top.truncate(k);
        }
        top
    }

    /// Batched dot-product scores: `out[q][i]` is query `q` against item
    /// `i`. Query-blocked for item-memory reuse; worker count from
    /// `NSCOG_THREADS` (see [`parallel::configured_threads`]).
    pub fn scores_batch(&self, queries: &[BinaryHV]) -> Vec<Vec<i64>> {
        self.scores_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::scores_batch`] with an explicit worker count.
    pub fn scores_batch_with(&self, queries: &[BinaryHV], threads: usize) -> Vec<Vec<i64>> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out: Vec<Vec<i64>> = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let base = out.len();
                out.extend(block.iter().map(|_| Vec::with_capacity(self.items.len())));
                for it in &self.items {
                    for (b, q) in block.iter().enumerate() {
                        out[base + b].push(it.dot_bulk(q));
                    }
                }
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Batched nearest-item search: one `(index, score)` per query, equal
    /// to calling [`Self::nearest`] per query (including first-wins tie
    /// behaviour) but query-blocked, Harley–Seal bulk-popcounted, and
    /// optionally threaded.
    pub fn nearest_batch(&self, queries: &[BinaryHV]) -> Vec<(usize, i64)> {
        self.nearest_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::nearest_batch`] with an explicit worker count.
    pub fn nearest_batch_with(&self, queries: &[BinaryHV], threads: usize) -> Vec<(usize, i64)> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let mut best = vec![(0usize, i64::MIN); block.len()];
                for (i, it) in self.items.iter().enumerate() {
                    for (b, q) in block.iter().enumerate() {
                        let s = it.dot_bulk(q);
                        if s > best[b].1 {
                            best[b] = (i, s);
                        }
                    }
                }
                out.extend(best);
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Memory footprint (bytes) of the full codebook.
    pub fn storage_bytes(&self) -> usize {
        self.len() * self.dim / 8
    }

    /// Memory footprint (bytes) when stored as CA-90 seeds only.
    pub fn compressed_bytes(&self) -> usize {
        self.len() * FOLD_BITS / 8
    }
}

/// A codebook of real-valued (bipolar) item vectors.
#[derive(Debug, Clone)]
pub struct RealCodebook {
    dim: usize,
    items: Vec<RealHV>,
}

impl RealCodebook {
    /// `n` random bipolar item vectors.
    pub fn random_bipolar(rng: &mut Rng, n: usize, dim: usize) -> Self {
        RealCodebook {
            dim,
            items: (0..n).map(|_| RealHV::random_bipolar(rng, dim)).collect(),
        }
    }

    /// `n` random HRR (Gaussian 1/sqrt(D)) item vectors for circular-conv
    /// binding (NVSA-style holographic codebooks).
    pub fn random_hrr(rng: &mut Rng, n: usize, dim: usize) -> Self {
        RealCodebook {
            dim,
            items: (0..n).map(|_| RealHV::random_hrr(rng, dim)).collect(),
        }
    }

    /// Build a codebook from pre-generated items, all of dimension `dim`.
    pub fn from_items(dim: usize, items: Vec<RealHV>) -> Self {
        for it in &items {
            assert_eq!(it.dim(), dim);
        }
        RealCodebook { dim, items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn item(&self, i: usize) -> &RealHV {
        &self.items[i]
    }

    pub fn items(&self) -> &[RealHV] {
        &self.items
    }

    /// Dot-product scores against every item.
    pub fn scores(&self, query: &RealHV) -> Vec<f64> {
        self.items.iter().map(|it| it.dot(query)).collect()
    }

    /// Nearest item by dot product.
    pub fn nearest(&self, query: &RealHV) -> (usize, f64) {
        let mut best = (0usize, f64::NEG_INFINITY);
        for (i, it) in self.items.iter().enumerate() {
            let s = it.dot(query);
            if s > best.1 {
                best = (i, s);
            }
        }
        best
    }

    /// Top-`k` items by score, ordered by (score desc, index asc) — same
    /// total order as [`BinaryCodebook::top_k`], so sharded scans merge
    /// identically on both codebook families.
    pub fn top_k(&self, query: &RealHV, k: usize) -> Vec<(usize, f64)> {
        assert_eq!(query.dim(), self.dim);
        let mut top: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
        if k == 0 {
            return top;
        }
        for (i, it) in self.items.iter().enumerate() {
            let s = it.dot(query);
            if top.len() == k && s <= top[k - 1].1 {
                continue;
            }
            let pos = top.partition_point(|&(_, ts)| ts >= s);
            top.insert(pos, (i, s));
            top.truncate(k);
        }
        top
    }

    /// Batched dot-product scores, query-blocked (`NSCOG_THREADS` workers).
    pub fn scores_batch(&self, queries: &[RealHV]) -> Vec<Vec<f64>> {
        self.scores_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::scores_batch`] with an explicit worker count.
    pub fn scores_batch_with(&self, queries: &[RealHV], threads: usize) -> Vec<Vec<f64>> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out: Vec<Vec<f64>> = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let base = out.len();
                out.extend(block.iter().map(|_| Vec::with_capacity(self.items.len())));
                for it in &self.items {
                    for (b, q) in block.iter().enumerate() {
                        out[base + b].push(it.dot(q));
                    }
                }
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Batched nearest-item search, equal to per-query [`Self::nearest`].
    pub fn nearest_batch(&self, queries: &[RealHV]) -> Vec<(usize, f64)> {
        self.nearest_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::nearest_batch`] with an explicit worker count.
    pub fn nearest_batch_with(&self, queries: &[RealHV], threads: usize) -> Vec<(usize, f64)> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let mut best = vec![(0usize, f64::NEG_INFINITY); block.len()];
                for (i, it) in self.items.iter().enumerate() {
                    for (b, q) in block.iter().enumerate() {
                        let s = it.dot(q);
                        if s > best[b].1 {
                            best[b] = (i, s);
                        }
                    }
                }
                out.extend(best);
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Fused resonator projection: `scores[k] = item_k · query`, then
    /// `out = sign(Σ_k scores[k] · item_k)` — the paper's d→c→sign chain
    /// in one pass, writing both outputs in place. `scores` keeps its
    /// capacity across calls and `out` is overwritten, so steady-state
    /// sweeps allocate nothing and the intermediate f32 weight vector of
    /// the unfused path disappears.
    pub fn project_signed_into(&self, query: &RealHV, scores: &mut Vec<f64>, out: &mut RealHV) {
        assert_eq!(query.dim(), self.dim);
        assert_eq!(out.dim(), self.dim);
        scores.clear();
        scores.extend(self.items.iter().map(|it| it.dot(query)));
        let o = out.as_mut_slice();
        for v in o.iter_mut() {
            *v = 0.0;
        }
        for (&s, item) in scores.iter().zip(&self.items) {
            let w = s as f32;
            if w == 0.0 {
                continue;
            }
            for (acc, &x) in o.iter_mut().zip(item.as_slice()) {
                *acc += w * x;
            }
        }
        for v in o.iter_mut() {
            *v = if *v >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// Probability-weighted bundle: PMF-to-VSA transform (NVSA).
    pub fn weighted_bundle(&self, pmf: &[f64]) -> RealHV {
        assert_eq!(pmf.len(), self.len());
        let mut out = RealHV::zeros(self.dim);
        for (w, item) in pmf.iter().zip(&self.items) {
            let o = out.as_mut_slice();
            let it = item.as_slice();
            for i in 0..o.len() {
                o[i] += (*w as f32) * it[i];
            }
        }
        out
    }

    /// VSA-to-PMF transform: ReLU'd similarity, normalized (NVSA).
    pub fn to_pmf(&self, query: &RealHV) -> Vec<f64> {
        let mut scores = self.scores(query);
        relu_normalize(&mut scores);
        scores
    }

    /// Batched [`Self::to_pmf`] through the query-blocked scan: result `q`
    /// equals `to_pmf(&queries[q])`. This is the NVSA decode path's hot
    /// loop (one scan per attribute instead of one per panel).
    pub fn to_pmf_batch(&self, queries: &[RealHV]) -> Vec<Vec<f64>> {
        let mut out = self.scores_batch(queries);
        for scores in &mut out {
            relu_normalize(scores);
        }
        out
    }

    /// f32 storage bytes.
    pub fn storage_bytes(&self) -> usize {
        self.len() * self.dim * 4
    }
}

/// Shared VSA-to-PMF normalization: ReLU then divide by the mass (if any).
fn relu_normalize(scores: &mut [f64]) {
    for s in scores.iter_mut() {
        *s = s.max(0.0);
    }
    let total: f64 = scores.iter().sum();
    if total > 1e-12 {
        for s in scores.iter_mut() {
            *s /= total;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_items_quasi_orthogonal() {
        let mut rng = Rng::new(1);
        let cb = BinaryCodebook::random(&mut rng, 16, 4096);
        for i in 0..16 {
            for j in 0..16 {
                let cos = cb.item(i).cosine(cb.item(j));
                if i == j {
                    assert!((cos - 1.0).abs() < 1e-12);
                } else {
                    assert!(cos.abs() < 0.12, "items {i},{j} cos {cos}");
                }
            }
        }
    }

    #[test]
    fn nearest_recovers_member() {
        let mut rng = Rng::new(2);
        let cb = BinaryCodebook::random(&mut rng, 64, 2048);
        for probe in [0usize, 13, 63] {
            let (idx, score) = cb.nearest(cb.item(probe));
            assert_eq!(idx, probe);
            assert_eq!(score, 2048);
        }
    }

    #[test]
    fn seed_roundtrip_preserves_fold0_and_determinism() {
        let mut rng = Rng::new(3);
        let cb = BinaryCodebook::from_seeds(
            &(0..8)
                .map(|_| (0..8).map(|_| rng.next_u64()).collect::<Vec<u64>>())
                .collect::<Vec<_>>(),
            4096,
        );
        let seeds = cb.seeds();
        let cb2 = BinaryCodebook::from_seeds(&seeds, 4096);
        for i in 0..8 {
            assert_eq!(cb.item(i), cb2.item(i));
        }
    }

    #[test]
    fn compression_ratio() {
        let mut rng = Rng::new(4);
        let cb = BinaryCodebook::random(&mut rng, 32, 8192);
        // 8192/512 = 16x compression from seed-only storage.
        assert_eq!(cb.storage_bytes() / cb.compressed_bytes(), 16);
    }

    #[test]
    fn real_nearest_recovers_member() {
        let mut rng = Rng::new(5);
        let cb = RealCodebook::random_bipolar(&mut rng, 32, 1024);
        let (idx, _) = cb.nearest(cb.item(17));
        assert_eq!(idx, 17);
    }

    #[test]
    fn weighted_bundle_peaks_at_argmax() {
        let mut rng = Rng::new(6);
        let cb = RealCodebook::random_bipolar(&mut rng, 8, 2048);
        let mut pmf = vec![0.02; 8];
        pmf[3] = 0.86;
        let v = cb.weighted_bundle(&pmf);
        let back = cb.to_pmf(&v);
        let argmax = back
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 3);
        assert!((back.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn binary_batch_matches_per_query() {
        let mut rng = Rng::new(8);
        let cb = BinaryCodebook::random(&mut rng, 37, 1024);
        let queries: Vec<BinaryHV> =
            (0..19).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        for threads in [1usize, 2, 5] {
            let nb = cb.nearest_batch_with(&queries, threads);
            let sb = cb.scores_batch_with(&queries, threads);
            for (q, query) in queries.iter().enumerate() {
                assert_eq!(nb[q], cb.nearest(query), "threads={threads} q={q}");
                assert_eq!(sb[q], cb.scores(query), "threads={threads} q={q}");
            }
        }
        assert!(cb.nearest_batch(&[]).is_empty());
    }

    #[test]
    fn real_batch_matches_per_query() {
        let mut rng = Rng::new(9);
        let cb = RealCodebook::random_bipolar(&mut rng, 21, 512);
        let queries: Vec<RealHV> =
            (0..11).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        for threads in [1usize, 3] {
            let nb = cb.nearest_batch_with(&queries, threads);
            let sb = cb.scores_batch_with(&queries, threads);
            for (q, query) in queries.iter().enumerate() {
                assert_eq!(nb[q], cb.nearest(query), "threads={threads} q={q}");
                assert_eq!(sb[q], cb.scores(query), "threads={threads} q={q}");
            }
        }
    }

    #[test]
    fn fused_projection_matches_unfused_chain() {
        use crate::vsa::ops;
        let mut rng = Rng::new(10);
        let cb = RealCodebook::random_bipolar(&mut rng, 12, 512);
        let query = RealHV::random_bipolar(&mut rng, 512);
        let mut scores = Vec::new();
        let mut out = RealHV::zeros(512);
        cb.project_signed_into(&query, &mut scores, &mut out);
        assert_eq!(scores, cb.scores(&query));
        let weights: Vec<f32> = scores.iter().map(|&s| s as f32).collect();
        let items: Vec<&RealHV> = cb.items().iter().collect();
        let expect = ops::weighted_sum(&weights, &items).sign();
        assert_eq!(out, expect);
    }

    /// Oracle: full sort by (score desc, index asc), then truncate.
    fn top_k_oracle<S: Copy + PartialOrd>(scores: &[S], k: usize) -> Vec<(usize, S)> {
        let mut all: Vec<(usize, S)> = scores.iter().copied().enumerate().collect();
        all.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap()
                .then(a.0.cmp(&b.0))
        });
        all.truncate(k);
        all
    }

    #[test]
    fn binary_top_k_matches_sort_oracle() {
        let mut rng = Rng::new(11);
        let cb = BinaryCodebook::random(&mut rng, 33, 512);
        let q = BinaryHV::random(&mut rng, 512);
        let scores = cb.scores(&q);
        for k in [0usize, 1, 3, 33, 50] {
            assert_eq!(cb.top_k(&q, k), top_k_oracle(&scores, k), "k={k}");
        }
        // k=1 agrees with nearest (first-wins ties)
        assert_eq!(cb.top_k(&q, 1)[0], cb.nearest(&q));
        // member query: exact match leads with the full-dim score
        assert_eq!(cb.top_k(cb.item(7), 2)[0], (7, 512));
    }

    #[test]
    fn binary_top_k_tie_prefers_lower_index() {
        // duplicate items force exact score ties
        let mut rng = Rng::new(12);
        let a = BinaryHV::random(&mut rng, 256);
        let b = BinaryHV::random(&mut rng, 256);
        let cb = BinaryCodebook::from_items(256, vec![a.clone(), b.clone(), a.clone()]);
        let top = cb.top_k(&a, 2);
        // indices 0 and 2 tie at the full-dim score: lower index ranks first
        assert_eq!(top[0], (0, 256));
        assert_eq!(top[1], (2, 256));
        assert_eq!(cb.nearest(&a), (0, 256));
        // with room for all three, the weak match comes last
        assert_eq!(cb.top_k(&a, 3)[2].0, 1);
    }

    #[test]
    fn real_top_k_matches_sort_oracle() {
        let mut rng = Rng::new(13);
        let cb = RealCodebook::random_bipolar(&mut rng, 21, 256);
        let q = RealHV::random_bipolar(&mut rng, 256);
        let scores = cb.scores(&q);
        for k in [1usize, 4, 21, 30] {
            assert_eq!(cb.top_k(&q, k), top_k_oracle(&scores, k), "k={k}");
        }
        assert_eq!(cb.top_k(&q, 1)[0], cb.nearest(&q));
    }

    #[test]
    fn from_items_round_trips() {
        let mut rng = Rng::new(14);
        let cb = BinaryCodebook::random(&mut rng, 9, 512);
        let rebuilt = BinaryCodebook::from_items(512, cb.items().to_vec());
        for i in 0..9 {
            assert_eq!(rebuilt.item(i), cb.item(i));
        }
        let rcb = RealCodebook::random_bipolar(&mut rng, 5, 128);
        let rrebuilt = RealCodebook::from_items(128, rcb.items().to_vec());
        assert_eq!(rrebuilt.item(3), rcb.item(3));
    }

    #[test]
    fn to_pmf_batch_matches_per_query() {
        let mut rng = Rng::new(15);
        let cb = RealCodebook::random_bipolar(&mut rng, 8, 512);
        let queries: Vec<RealHV> =
            (0..5).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        let batch = cb.to_pmf_batch(&queries);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(batch[q], cb.to_pmf(query), "query {q}");
        }
    }

    #[test]
    fn to_pmf_of_orthogonal_query_is_spread() {
        let mut rng = Rng::new(7);
        let cb = RealCodebook::random_bipolar(&mut rng, 8, 2048);
        let q = RealHV::random_bipolar(&mut rng, 2048);
        let pmf = cb.to_pmf(&q);
        assert!(pmf.iter().all(|&p| p < 0.9));
    }
}
