//! Item-memory codebooks: arrays of atomic hypervectors used for symbolic
//! encoding, with optional CA-90 compressed storage.

use super::ca90;
use super::hypervector::{BinaryHV, DotAcc, RealHV, FOLD_BITS, FOLD_WORDS};
use super::kernels::{self, xor_hamming};
use super::sketch::{
    default_sketch_bits, query_suffix_norms, real_upper_bound, BinarySketch, PruneStats,
    RealSketch, PRUNE_CHUNK_WORDS, REAL_PRUNE_CHUNK,
};
use crate::util::{parallel, Rng};

/// Queries per block in the batched scans: each item row is streamed from
/// memory once per block while the block's queries stay cache-resident,
/// so item-memory traffic drops by ~QUERY_BLOCK× versus per-query scans.
const QUERY_BLOCK: usize = 8;

/// Insert `(i, s)` into a list kept sorted under the global
/// (score desc, index asc) total order, truncated to `k`. Equivalent to
/// the exhaustive scans' in-index-order insertion for any visit order,
/// which is what lets the pruned scans visit items most-promising-first.
fn insert_ranked<S: PartialOrd + Copy>(top: &mut Vec<(usize, S)>, i: usize, s: S, k: usize) {
    let pos = top.partition_point(|&(tj, ts)| ts > s || (ts == s && tj < i));
    top.insert(pos, (i, s));
    top.truncate(k);
}

/// A codebook of binary item vectors, carrying an optional
/// [`BinarySketch`] prefilter sidecar for the bound-pruned scans.
///
/// Two storage backings share one scan contract:
/// - **ram** (default): every row fully materialized in `items`;
/// - **ca90** ([`Self::ca90_from_seeds`]): only the per-item 512-bit
///   seed folds are resident (`seeds_flat`, item-major) and rows are
///   regenerated fold-by-fold *inside* the bounded scan loops via
///   [`ca90::ca90_step_into`] — compute traded for DRAM streaming, the
///   paper's CA-90 co-design. Results are bit-identical across backings
///   (same (score desc, index asc) total order, same `dim - 2·hamming`
///   scores; see `rust/tests/remat_equivalence.rs`).
#[derive(Debug, Clone)]
pub struct BinaryCodebook {
    dim: usize,
    items: Vec<BinaryHV>,
    /// `Some` = CA-90 seeds-only backing: `n_items · FOLD_WORDS` words,
    /// item-major; `items` is empty then.
    seeds_flat: Option<Vec<u64>>,
    n_items: usize,
    sketch: Option<BinarySketch>,
}

impl BinaryCodebook {
    /// Assemble a codebook and its default-width sketch sidecar (item
    /// sets are immutable after construction, so the sidecar never goes
    /// stale).
    fn assemble(dim: usize, items: Vec<BinaryHV>) -> Self {
        let sketch = BinarySketch::build(&items, default_sketch_bits(dim));
        let n_items = items.len();
        BinaryCodebook { dim, items, seeds_flat: None, n_items, sketch }
    }

    /// Generate `n` random item vectors of dimension `dim`.
    pub fn random(rng: &mut Rng, n: usize, dim: usize) -> Self {
        Self::assemble(dim, (0..n).map(|_| BinaryHV::random(rng, dim)).collect())
    }

    /// Reconstruct a full codebook from per-item 512-bit seed folds via
    /// CA-90 expansion (the accelerator's compressed storage scheme).
    ///
    /// The expansion is fused: item rows are generated fold-by-fold in
    /// place ([`ca90::expand_vector`] streams generations with no
    /// per-fold scratch allocation) and the sketch prefilter sidecar is
    /// built **directly from the seeds**
    /// ([`BinarySketch::build_from_seeds`]) — the default sketch is one
    /// fold, i.e. the seed itself — so construction never re-reads the
    /// materialized rows. Identical to building the sketch from the
    /// expanded items (fold 0 is copied verbatim either way;
    /// property-tested).
    pub fn from_seeds(seeds: &[Vec<u64>], dim: usize) -> Self {
        let sketch =
            BinarySketch::build_from_seeds(seeds, FOLD_BITS, dim / 64, default_sketch_bits(dim));
        let items: Vec<BinaryHV> = seeds
            .iter()
            .map(|s| ca90::expand_vector(s, FOLD_BITS, dim))
            .collect();
        let n_items = items.len();
        BinaryCodebook { dim, items, seeds_flat: None, n_items, sketch }
    }

    /// Seeds-only (CA-90 rematerialization) backing: keep just the
    /// per-item seed folds resident and regenerate rows on demand inside
    /// the scan loops. `dim` must be a positive multiple of
    /// [`FOLD_BITS`] (the CA-90 expansion constraint). The sketch
    /// sidecar is built straight from the seeds
    /// ([`BinarySketch::build_from_seeds`]) at `sketch_bits` (`None` =
    /// the per-dimension default), so nothing wider than the sidecar is
    /// ever materialized at build time.
    pub fn ca90_from_seeds(seeds: &[Vec<u64>], dim: usize, sketch_bits: Option<usize>) -> Self {
        assert!(
            dim >= FOLD_BITS && dim % FOLD_BITS == 0,
            "ca90 backing requires dim to be a positive multiple of {FOLD_BITS} (got {dim})"
        );
        let bits = sketch_bits.unwrap_or_else(|| default_sketch_bits(dim));
        let sketch = BinarySketch::build_from_seeds(seeds, FOLD_BITS, dim / 64, bits);
        let mut flat = Vec::with_capacity(seeds.len() * FOLD_WORDS);
        for s in seeds {
            assert_eq!(s.len(), FOLD_WORDS);
            flat.extend_from_slice(s);
        }
        BinaryCodebook {
            dim,
            items: Vec::new(),
            seeds_flat: Some(flat),
            n_items: seeds.len(),
            sketch,
        }
    }

    /// Whether this codebook is CA-90 (seeds-only) backed.
    pub fn is_ca90(&self) -> bool {
        self.seeds_flat.is_some()
    }

    /// Stable backing name for telemetry and the bench JSONs.
    pub fn backing_name(&self) -> &'static str {
        if self.is_ca90() { "ca90" } else { "ram" }
    }

    /// Materialize item `i`'s full row regardless of backing (allocates;
    /// oracles and mutation paths only — scans never call this).
    pub fn materialize_item(&self, i: usize) -> BinaryHV {
        match &self.seeds_flat {
            Some(flat) => ca90::expand_vector(
                &flat[i * FOLD_WORDS..(i + 1) * FOLD_WORDS],
                FOLD_BITS,
                self.dim,
            ),
            None => self.items[i].clone(),
        }
    }

    /// A fully materialized (ram-backed) twin with the same rows and
    /// sketch width — the reference the remat property tests scan.
    pub fn materialized(&self) -> BinaryCodebook {
        match &self.seeds_flat {
            Some(_) => {
                let items: Vec<BinaryHV> =
                    (0..self.n_items).map(|i| self.materialize_item(i)).collect();
                let bits = self.sketch.as_ref().map(|s| s.bits()).unwrap_or(0);
                let mut cb = Self::from_items_sketched(self.dim, items, Some(bits));
                if let (Some(dst), Some(src)) = (cb.sketch.as_mut(), self.sketch.as_ref()) {
                    if src.coarse_words() > 0 {
                        dst.enable_cascade(src.coarse_bits());
                    }
                }
                cb
            }
            None => self.clone(),
        }
    }

    /// Build a codebook from pre-generated items, all of dimension `dim`
    /// (e.g. a contiguous slice of another codebook when sharding).
    pub fn from_items(dim: usize, items: Vec<BinaryHV>) -> Self {
        Self::from_items_sketched(dim, items, None)
    }

    /// [`Self::from_items`] with an explicit sketch width (`None` = the
    /// per-dimension default), so callers that already know their width
    /// — e.g. sharding under `--sketch-bits` — build the sidecar once
    /// instead of building the default and rebuilding.
    pub fn from_items_sketched(
        dim: usize,
        items: Vec<BinaryHV>,
        sketch_bits: Option<usize>,
    ) -> Self {
        for it in &items {
            assert_eq!(it.dim(), dim);
        }
        match sketch_bits {
            None => Self::assemble(dim, items),
            Some(bits) => {
                let sketch = BinarySketch::build(&items, bits);
                let n_items = items.len();
                BinaryCodebook { dim, items, seeds_flat: None, n_items, sketch }
            }
        }
    }

    /// Rebuild the sketch sidecar at an explicit width (`--sketch-bits`
    /// serving knob); 0 or a width ≥ the row drops the sidecar, leaving
    /// the pruned scans on incremental bounds alone. Cascade state is
    /// reset (re-enable via [`Self::enable_cascade`]).
    pub fn rebuild_sketch(&mut self, sketch_bits: usize) {
        self.sketch = match &self.seeds_flat {
            Some(flat) => {
                let seeds: Vec<Vec<u64>> = flat.chunks(FOLD_WORDS).map(|s| s.to_vec()).collect();
                BinarySketch::build_from_seeds(&seeds, FOLD_BITS, self.dim / 64, sketch_bits)
            }
            None => BinarySketch::build(&self.items, sketch_bits),
        };
    }

    /// Enable the hierarchical sketch cascade at `coarse_bits` (see
    /// [`BinarySketch::enable_cascade`]); returns whether a coarse level
    /// is now active (requires an active sketch strictly wider than the
    /// coarse level).
    pub fn enable_cascade(&mut self, coarse_bits: usize) -> bool {
        match self.sketch.as_mut() {
            Some(sk) => sk.enable_cascade(coarse_bits),
            None => false,
        }
    }

    /// The prefilter sidecar, if one is active.
    pub fn sketch(&self) -> Option<&BinarySketch> {
        self.sketch.as_ref()
    }

    /// Extract seed folds (fold 0 of each item) for compressed storage.
    pub fn seeds(&self) -> Vec<Vec<u64>> {
        match &self.seeds_flat {
            Some(flat) => flat.chunks(FOLD_WORDS).map(|s| s.to_vec()).collect(),
            None => self
                .items
                .iter()
                .map(|hv| hv.words()[..FOLD_WORDS.min(hv.words().len())].to_vec())
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.n_items
    }

    pub fn is_empty(&self) -> bool {
        self.n_items == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn item(&self, i: usize) -> &BinaryHV {
        assert!(
            self.seeds_flat.is_none(),
            "item(): ca90-backed codebook holds seeds only — use materialize_item()"
        );
        &self.items[i]
    }

    pub fn items(&self) -> &[BinaryHV] {
        assert!(
            self.seeds_flat.is_none(),
            "items(): ca90-backed codebook holds seeds only — use materialized()/seeds()"
        );
        &self.items
    }

    /// Visit every row in index order as a word slice. The ram backing
    /// borrows rows in place; the ca90 backing rematerializes each row
    /// into one reused scratch buffer (a single allocation per call,
    /// never per item). The exhaustive scans and batch paths funnel
    /// through this so both backings share one loop body.
    fn for_each_row<F: FnMut(usize, &[u64])>(&self, mut f: F) {
        match &self.seeds_flat {
            Some(flat) => {
                let mut row = vec![0u64; self.dim / 64];
                for i in 0..self.n_items {
                    ca90::expand_into(
                        &flat[i * FOLD_WORDS..(i + 1) * FOLD_WORDS],
                        FOLD_BITS,
                        &mut row,
                    );
                    f(i, &row);
                }
            }
            None => {
                for (i, it) in self.items.iter().enumerate() {
                    f(i, it.words());
                }
            }
        }
    }

    /// Dot-product scores of `query` against every item (allocating
    /// convenience over [`Self::scores_into`]).
    pub fn scores(&self, query: &BinaryHV) -> Vec<i64> {
        let mut out = Vec::new();
        self.scores_into(query, &mut out);
        out
    }

    /// Nearest item index and its score (paper's e(y) = argmax d).
    pub fn nearest(&self, query: &BinaryHV) -> (usize, i64) {
        let dim = self.dim as i64;
        let qw = query.words();
        let mut best = (0usize, i64::MIN);
        self.for_each_row(|i, row| {
            let s = dim - 2 * xor_hamming(row, qw) as i64;
            if s > best.1 {
                best = (i, s);
            }
        });
        best
    }

    /// Top-`k` items by score, ordered by (score desc, index asc) — the
    /// total order every sharded/merged scan in [`crate::serve`] must
    /// reproduce, so `top_k(k')[..k]` is prefix-stable for any `k' ≥ k`
    /// and per-shard top-k lists merge into exactly this list.
    pub fn top_k(&self, query: &BinaryHV, k: usize) -> Vec<(usize, i64)> {
        assert_eq!(query.dim(), self.dim);
        let mut top: Vec<(usize, i64)> = Vec::with_capacity(k + 1);
        if k == 0 {
            return top;
        }
        let dim = self.dim as i64;
        let qw = query.words();
        self.for_each_row(|i, row| {
            let s = dim - 2 * xor_hamming(row, qw) as i64;
            // equal scores keep the earlier (smaller) index, matching
            // `nearest`'s first-wins tie rule
            if top.len() == k && s <= top[k - 1].1 {
                return;
            }
            let pos = top.partition_point(|&(_, ts)| ts >= s);
            top.insert(pos, (i, s));
            top.truncate(k);
        });
        top
    }

    /// Stream one item row from `start_w` with `ham0` already accumulated
    /// (the sketch prefix), terminating as soon as the incremental bound
    /// proves the item cannot beat `top`'s k-th entry under the
    /// (score desc, index asc) total order. Returns the exact final score
    /// for survivors, `None` for early-terminated items.
    #[inline]
    fn scan_item_bounded(
        &self,
        i: usize,
        qw: &[u64],
        start_w: usize,
        ham0: u32,
        k: usize,
        top: &[(usize, i64)],
        stats: &mut PruneStats,
    ) -> Option<i64> {
        if self.seeds_flat.is_some() {
            return self.scan_item_bounded_ca90(i, qw, start_w, ham0, k, top, stats);
        }
        let words = self.items[i].words();
        let n_words = words.len();
        let dim = self.dim as i64;
        let mut ham = ham0;
        let mut w = start_w;
        while w < n_words {
            let e = (w + PRUNE_CHUNK_WORDS).min(n_words);
            ham += xor_hamming(&words[w..e], &qw[w..e]);
            stats.words_streamed += (e - w) as u64;
            w = e;
            if w < n_words && top.len() == k {
                let ub = dim - 2 * ham as i64;
                let (kj, ks) = top[k - 1];
                if !(ub > ks || (ub == ks && i < kj)) {
                    stats.early_terminated += 1;
                    return None;
                }
            }
        }
        Some(dim - 2 * ham as i64)
    }

    /// [`Self::scan_item_bounded`] for the CA-90 backing: the row is never
    /// resident, so each 512-bit fold is regenerated into a stack
    /// ping-pong pair with [`ca90::ca90_step_into`] and consumed
    /// immediately. The bound check runs between folds (the same
    /// `PRUNE_CHUNK_WORDS = FOLD_WORDS` cadence as the ram path), so an
    /// early-terminated item also stops *generating* — pruning saves
    /// CA-90 steps here the way it saves DRAM reads on the ram backing.
    /// `words_streamed` counts regenerated-and-consumed words, keeping
    /// the `words_frac ≤ 1` roofline invariant comparable across
    /// backings.
    fn scan_item_bounded_ca90(
        &self,
        i: usize,
        qw: &[u64],
        start_w: usize,
        ham0: u32,
        k: usize,
        top: &[(usize, i64)],
        stats: &mut PruneStats,
    ) -> Option<i64> {
        let flat = self.seeds_flat.as_ref().expect("ca90 backing");
        let n_words = self.dim / 64;
        let n_folds = n_words / FOLD_WORDS;
        let dim = self.dim as i64;
        let mut state = [0u64; FOLD_WORDS];
        let mut next = [0u64; FOLD_WORDS];
        state.copy_from_slice(&flat[i * FOLD_WORDS..(i + 1) * FOLD_WORDS]);
        let mut ham = ham0;
        for f in 0..n_folds {
            let w0 = f * FOLD_WORDS;
            let w1 = w0 + FOLD_WORDS;
            if w1 > start_w {
                let lo = w0.max(start_w);
                ham += xor_hamming(&state[lo - w0..], &qw[lo..w1]);
                stats.words_streamed += (w1 - lo) as u64;
                if w1 < n_words && top.len() == k {
                    let ub = dim - 2 * ham as i64;
                    let (kj, ks) = top[k - 1];
                    if !(ub > ks || (ub == ks && i < kj)) {
                        stats.early_terminated += 1;
                        return None;
                    }
                }
            }
            if f + 1 < n_folds {
                ca90::ca90_step_into(&state, &mut next, FOLD_BITS);
                std::mem::swap(&mut state, &mut next);
            }
        }
        Some(dim - 2 * ham as i64)
    }

    /// Bound-pruned top-`k`: bit-identical to [`Self::top_k`] (same
    /// (score desc, index asc) order, same ties) while streaming fewer
    /// item words. Cascade: sketch pass over the contiguous sidecar →
    /// visit items most-promising-first → reject on the prefix bound →
    /// survivors finish their rows under the incremental bound. `order`
    /// is a reusable scratch buffer (cleared each call).
    pub fn top_k_pruned_with_buf(
        &self,
        query: &BinaryHV,
        k: usize,
        stats: &mut PruneStats,
        order: &mut Vec<(u32, u32)>,
    ) -> Vec<(usize, i64)> {
        assert_eq!(query.dim(), self.dim);
        let mut top: Vec<(usize, i64)> = Vec::with_capacity(k + 1);
        if k == 0 || self.is_empty() {
            return top;
        }
        let n = self.len();
        let n_words = self.dim / 64;
        let dim = self.dim as i64;
        let qw = query.words();
        stats.items += n as u64;
        stats.words_total += (n * n_words) as u64;
        if let Some(sk) = &self.sketch {
            let sw = sk.words_per_item();
            // cascade: when a coarse level exists, order and bulk-reject
            // on it (n·cw words instead of n·sw); survivors refine the
            // coarse Hamming to the full sketch prefix one item at a time
            let cw = sk.coarse_words();
            order.clear();
            if cw > 0 {
                for i in 0..n {
                    order.push((xor_hamming(sk.coarse_row(i), &qw[..cw]), i as u32));
                }
                stats.words_streamed += (n * cw) as u64;
            } else {
                for i in 0..n {
                    order.push((xor_hamming(sk.row(i), &qw[..sw]), i as u32));
                }
                stats.words_streamed += (n * sw) as u64;
            }
            // ascending prefix Hamming = descending upper bound; index
            // breaks ties deterministically
            order.sort_unstable();
            for pos in 0..order.len() {
                let (hp, iu) = order[pos];
                let i = iu as usize;
                if top.len() == k {
                    let ub = dim - 2 * hp as i64;
                    let (kj, ks) = top[k - 1];
                    if ub < ks {
                        // sorted order: every later item bounds ≤ ub < ks
                        let tail = (order.len() - pos) as u64;
                        if cw > 0 {
                            stats.coarse_rejected += tail;
                        } else {
                            stats.sketch_rejected += tail;
                        }
                        break;
                    }
                    if !(ub > ks || i < kj) {
                        if cw > 0 {
                            stats.coarse_rejected += 1;
                        } else {
                            stats.sketch_rejected += 1;
                        }
                        continue;
                    }
                }
                let hp = if cw > 0 {
                    // coarse survivor: extend to the full sketch prefix
                    // and re-check before streaming the row
                    let h = hp + xor_hamming(&sk.row(i)[cw..], &qw[cw..sw]);
                    stats.words_streamed += (sw - cw) as u64;
                    if top.len() == k {
                        let ub = dim - 2 * h as i64;
                        let (kj, ks) = top[k - 1];
                        if !(ub > ks || (ub == ks && i < kj)) {
                            stats.sketch_rejected += 1;
                            continue;
                        }
                    }
                    h
                } else {
                    hp
                };
                if let Some(s) = self.scan_item_bounded(i, qw, sw, hp, k, &top, stats) {
                    if top.len() == k {
                        let (kj, ks) = top[k - 1];
                        if !(s > ks || (s == ks && i < kj)) {
                            continue;
                        }
                    }
                    insert_ranked(&mut top, i, s, k);
                }
            }
        } else {
            for i in 0..n {
                if let Some(s) = self.scan_item_bounded(i, qw, 0, 0, k, &top, stats) {
                    if top.len() == k {
                        let (kj, ks) = top[k - 1];
                        if !(s > ks || (s == ks && i < kj)) {
                            continue;
                        }
                    }
                    insert_ranked(&mut top, i, s, k);
                }
            }
        }
        top
    }

    /// [`Self::top_k_pruned_with_buf`] with an internal scratch buffer.
    pub fn top_k_pruned(
        &self,
        query: &BinaryHV,
        k: usize,
        stats: &mut PruneStats,
    ) -> Vec<(usize, i64)> {
        let mut order = Vec::new();
        self.top_k_pruned_with_buf(query, k, stats, &mut order)
    }

    /// Bound-pruned nearest: bit-identical to [`Self::nearest`]
    /// (first-wins ties) while streaming fewer words. Drives the same
    /// [`Self::scan_item_bounded`] helper as the top-k path over a fixed
    /// top-1 slice, so it stays allocation-free given the `order`
    /// scratch buffer without duplicating the bound logic.
    pub fn nearest_pruned_with_buf(
        &self,
        query: &BinaryHV,
        stats: &mut PruneStats,
        order: &mut Vec<(u32, u32)>,
    ) -> (usize, i64) {
        assert_eq!(query.dim(), self.dim);
        if self.is_empty() {
            return (0, i64::MIN);
        }
        let n = self.len();
        let n_words = self.dim / 64;
        let dim = self.dim as i64;
        let qw = query.words();
        stats.items += n as u64;
        stats.words_total += (n * n_words) as u64;
        // top-1 as a fixed slice: `&top1[..filled]` is the `top` the
        // shared helper bounds against (empty until the first survivor)
        let mut top1 = [(0usize, i64::MIN)];
        let mut filled = 0usize;
        if let Some(sk) = &self.sketch {
            let sw = sk.words_per_item();
            let cw = sk.coarse_words();
            order.clear();
            if cw > 0 {
                for i in 0..n {
                    order.push((xor_hamming(sk.coarse_row(i), &qw[..cw]), i as u32));
                }
                stats.words_streamed += (n * cw) as u64;
            } else {
                for i in 0..n {
                    order.push((xor_hamming(sk.row(i), &qw[..sw]), i as u32));
                }
                stats.words_streamed += (n * sw) as u64;
            }
            order.sort_unstable();
            for pos in 0..order.len() {
                let (hp, iu) = order[pos];
                let i = iu as usize;
                if filled == 1 {
                    let ub = dim - 2 * hp as i64;
                    let (bj, bs) = top1[0];
                    if ub < bs {
                        let tail = (order.len() - pos) as u64;
                        if cw > 0 {
                            stats.coarse_rejected += tail;
                        } else {
                            stats.sketch_rejected += tail;
                        }
                        break;
                    }
                    if !(ub > bs || i < bj) {
                        if cw > 0 {
                            stats.coarse_rejected += 1;
                        } else {
                            stats.sketch_rejected += 1;
                        }
                        continue;
                    }
                }
                let hp = if cw > 0 {
                    let h = hp + xor_hamming(&sk.row(i)[cw..], &qw[cw..sw]);
                    stats.words_streamed += (sw - cw) as u64;
                    if filled == 1 {
                        let ub = dim - 2 * h as i64;
                        let (bj, bs) = top1[0];
                        if !(ub > bs || (ub == bs && i < bj)) {
                            stats.sketch_rejected += 1;
                            continue;
                        }
                    }
                    h
                } else {
                    hp
                };
                if let Some(s) = self.scan_item_bounded(i, qw, sw, hp, 1, &top1[..filled], stats)
                {
                    let (bj, bs) = top1[0];
                    if filled == 1 && !(s > bs || (s == bs && i < bj)) {
                        continue;
                    }
                    top1[0] = (i, s);
                    filled = 1;
                }
            }
        } else {
            for i in 0..n {
                if let Some(s) = self.scan_item_bounded(i, qw, 0, 0, 1, &top1[..filled], stats) {
                    let (bj, bs) = top1[0];
                    if filled == 1 && !(s > bs || (s == bs && i < bj)) {
                        continue;
                    }
                    top1[0] = (i, s);
                    filled = 1;
                }
            }
        }
        top1[0]
    }

    /// [`Self::nearest_pruned_with_buf`] with an internal scratch buffer.
    pub fn nearest_pruned(&self, query: &BinaryHV, stats: &mut PruneStats) -> (usize, i64) {
        let mut order = Vec::new();
        self.nearest_pruned_with_buf(query, stats, &mut order)
    }

    /// Batched bound-pruned nearest: result `q` is bit-identical to
    /// [`Self::nearest`]`(&queries[q])`; prune telemetry for the whole
    /// batch is merged into the returned [`PruneStats`].
    pub fn nearest_batch_pruned_with(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, i64)>, PruneStats) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut st = PruneStats::default();
            let mut order = Vec::new();
            let out: Vec<(usize, i64)> = queries[r]
                .iter()
                .map(|q| self.nearest_pruned_with_buf(q, &mut st, &mut order))
                .collect();
            (out, st)
        });
        let mut stats = PruneStats::default();
        let mut out = Vec::with_capacity(queries.len());
        for (part, st) in parts {
            out.extend(part);
            stats.merge(&st);
        }
        (out, stats)
    }

    /// Batched bound-pruned top-`k` (see [`Self::top_k_pruned_with_buf`]).
    pub fn top_k_batch_pruned_with(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, i64)>>, PruneStats) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut st = PruneStats::default();
            let mut order = Vec::new();
            let out: Vec<Vec<(usize, i64)>> = queries[r]
                .iter()
                .map(|q| self.top_k_pruned_with_buf(q, k, &mut st, &mut order))
                .collect();
            (out, st)
        });
        let mut stats = PruneStats::default();
        let mut out = Vec::with_capacity(queries.len());
        for (part, st) in parts {
            out.extend(part);
            stats.merge(&st);
        }
        (out, stats)
    }

    /// [`Self::scores`] into a caller-held buffer: steady-state callers
    /// reuse one allocation across scans.
    pub fn scores_into(&self, query: &BinaryHV, out: &mut Vec<i64>) {
        assert_eq!(query.dim(), self.dim);
        out.clear();
        out.reserve(self.len());
        let dim = self.dim as i64;
        let qw = query.words();
        self.for_each_row(|_, row| out.push(dim - 2 * xor_hamming(row, qw) as i64));
    }

    /// [`Self::scores_batch_with`] into caller-held buffers: once `out`'s
    /// outer and inner vectors have warmed to the batch shape, repeated
    /// single-threaded calls perform zero heap allocation (enforced by
    /// `rust/tests/alloc_free.rs`). With `threads > 1` the scan fans out
    /// through scoped threads, which allocate per call; results are moved
    /// into `out` either way.
    pub fn scores_batch_into(&self, queries: &[BinaryHV], threads: usize, out: &mut Vec<Vec<i64>>) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        if threads > 1 && queries.len() > 1 {
            *out = self.scores_batch_with(queries, threads);
            return;
        }
        out.truncate(queries.len());
        while out.len() < queries.len() {
            out.push(Vec::with_capacity(self.len()));
        }
        for o in out.iter_mut() {
            o.clear();
        }
        let dim = self.dim as i64;
        let mut base = 0;
        while base < queries.len() {
            let end = (base + QUERY_BLOCK).min(queries.len());
            let nb = end - base;
            // fixed-size query-pointer block: one row load feeds all
            // `nb` accumulators in the SIMD kernel, zero heap churn
            let mut qws: [&[u64]; QUERY_BLOCK] = [&[]; QUERY_BLOCK];
            for (b, q) in queries[base..end].iter().enumerate() {
                qws[b] = q.words();
            }
            let mut hams = [0u32; QUERY_BLOCK];
            self.for_each_row(|_, row| {
                kernels::xor_hamming_block(row, &qws[..nb], &mut hams[..nb]);
                for b in 0..nb {
                    out[base + b].push(dim - 2 * hams[b] as i64);
                }
            });
            base = end;
        }
    }

    /// Batched dot-product scores: `out[q][i]` is query `q` against item
    /// `i`. Query-blocked for item-memory reuse; worker count from
    /// `NSCOG_THREADS` (see [`parallel::configured_threads`]).
    pub fn scores_batch(&self, queries: &[BinaryHV]) -> Vec<Vec<i64>> {
        self.scores_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::scores_batch`] with an explicit worker count.
    pub fn scores_batch_with(&self, queries: &[BinaryHV], threads: usize) -> Vec<Vec<i64>> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let dim = self.dim as i64;
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out: Vec<Vec<i64>> = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let base = out.len();
                out.extend(block.iter().map(|_| Vec::with_capacity(self.len())));
                let nb = block.len();
                let mut qws: [&[u64]; QUERY_BLOCK] = [&[]; QUERY_BLOCK];
                for (b, q) in block.iter().enumerate() {
                    qws[b] = q.words();
                }
                let mut hams = [0u32; QUERY_BLOCK];
                self.for_each_row(|_, row| {
                    kernels::xor_hamming_block(row, &qws[..nb], &mut hams[..nb]);
                    for b in 0..nb {
                        out[base + b].push(dim - 2 * hams[b] as i64);
                    }
                });
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Batched nearest-item search: one `(index, score)` per query, equal
    /// to calling [`Self::nearest`] per query (including first-wins tie
    /// behaviour) but query-blocked, Harley–Seal bulk-popcounted, and
    /// optionally threaded.
    pub fn nearest_batch(&self, queries: &[BinaryHV]) -> Vec<(usize, i64)> {
        self.nearest_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::nearest_batch`] with an explicit worker count.
    pub fn nearest_batch_with(&self, queries: &[BinaryHV], threads: usize) -> Vec<(usize, i64)> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let dim = self.dim as i64;
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let mut best = vec![(0usize, i64::MIN); block.len()];
                let nb = block.len();
                let mut qws: [&[u64]; QUERY_BLOCK] = [&[]; QUERY_BLOCK];
                for (b, q) in block.iter().enumerate() {
                    qws[b] = q.words();
                }
                let mut hams = [0u32; QUERY_BLOCK];
                self.for_each_row(|i, row| {
                    kernels::xor_hamming_block(row, &qws[..nb], &mut hams[..nb]);
                    for b in 0..nb {
                        let s = dim - 2 * hams[b] as i64;
                        if s > best[b].1 {
                            best[b] = (i, s);
                        }
                    }
                });
                out.extend(best);
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Memory footprint (bytes) of the full codebook.
    pub fn storage_bytes(&self) -> usize {
        self.len() * self.dim / 8
    }

    /// Memory footprint (bytes) when stored as CA-90 seeds only.
    pub fn compressed_bytes(&self) -> usize {
        self.len() * FOLD_BITS / 8
    }

    /// Bytes actually resident for this codebook's rows: full rows (ram)
    /// or seed folds only (ca90). Excludes sketch sidecars — see
    /// [`Self::sketch_resident_bytes`].
    pub fn row_resident_bytes(&self) -> usize {
        match &self.seeds_flat {
            Some(flat) => flat.len() * 8,
            None => self.items.len() * self.dim / 8,
        }
    }

    /// Bytes resident for the sketch sidecar(s), cascade level included.
    pub fn sketch_resident_bytes(&self) -> usize {
        self.sketch.as_ref().map_or(0, |s| s.storage_bytes())
    }

    /// Total resident bytes (rows + sketch sidecars): the memory-axis
    /// half of the CA-90 trade-off the serve bench reports.
    pub fn resident_bytes(&self) -> usize {
        self.row_resident_bytes() + self.sketch_resident_bytes()
    }
}

/// A codebook of real-valued (bipolar) item vectors, carrying an
/// optional [`RealSketch`] sidecar for the bound-pruned scans.
#[derive(Debug, Clone)]
pub struct RealCodebook {
    dim: usize,
    items: Vec<RealHV>,
    sketch: Option<RealSketch>,
}

impl RealCodebook {
    /// Assemble a codebook and its scan sidecar (items are immutable
    /// after construction, so the sidecar never goes stale).
    fn assemble(dim: usize, items: Vec<RealHV>) -> Self {
        let sketch = RealSketch::build(&items, REAL_PRUNE_CHUNK);
        RealCodebook { dim, items, sketch }
    }

    /// `n` random bipolar item vectors.
    pub fn random_bipolar(rng: &mut Rng, n: usize, dim: usize) -> Self {
        Self::assemble(dim, (0..n).map(|_| RealHV::random_bipolar(rng, dim)).collect())
    }

    /// `n` random HRR (Gaussian 1/sqrt(D)) item vectors for circular-conv
    /// binding (NVSA-style holographic codebooks).
    pub fn random_hrr(rng: &mut Rng, n: usize, dim: usize) -> Self {
        Self::assemble(dim, (0..n).map(|_| RealHV::random_hrr(rng, dim)).collect())
    }

    /// Build a codebook from pre-generated items, all of dimension `dim`.
    pub fn from_items(dim: usize, items: Vec<RealHV>) -> Self {
        for it in &items {
            assert_eq!(it.dim(), dim);
        }
        Self::assemble(dim, items)
    }

    /// The scan sidecar, if one is active (rows longer than one chunk).
    pub fn sketch(&self) -> Option<&RealSketch> {
        self.sketch.as_ref()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn item(&self, i: usize) -> &RealHV {
        &self.items[i]
    }

    pub fn items(&self) -> &[RealHV] {
        &self.items
    }

    /// Dot-product scores against every item (allocating convenience
    /// over [`Self::scores_into`]).
    pub fn scores(&self, query: &RealHV) -> Vec<f64> {
        let mut out = Vec::new();
        self.scores_into(query, &mut out);
        out
    }

    /// Nearest item by dot product.
    pub fn nearest(&self, query: &RealHV) -> (usize, f64) {
        let mut best = (0usize, f64::NEG_INFINITY);
        for (i, it) in self.items.iter().enumerate() {
            let s = it.dot(query);
            if s > best.1 {
                best = (i, s);
            }
        }
        best
    }

    /// Top-`k` items by score, ordered by (score desc, index asc) — same
    /// total order as [`BinaryCodebook::top_k`], so sharded scans merge
    /// identically on both codebook families.
    pub fn top_k(&self, query: &RealHV, k: usize) -> Vec<(usize, f64)> {
        assert_eq!(query.dim(), self.dim);
        let mut top: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
        if k == 0 {
            return top;
        }
        for (i, it) in self.items.iter().enumerate() {
            let s = it.dot(query);
            if top.len() == k && s <= top[k - 1].1 {
                continue;
            }
            let pos = top.partition_point(|&(_, ts)| ts >= s);
            top.insert(pos, (i, s));
            top.truncate(k);
        }
        top
    }

    /// Finish one item row from chunk `start_c` with `acc` already
    /// holding the exact partial dot, terminating when the
    /// Cauchy–Schwarz incremental bound proves the item cannot beat
    /// `top`'s k-th entry. Accumulation continues the canonical
    /// lane-strided schedule through [`DotAcc`] (the carried lanes and
    /// phase resume exactly where the sketch prefix stopped), so a
    /// survivor's score is bit-identical to [`RealHV::dot`] on every
    /// SIMD tier.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn scan_real_item_bounded(
        &self,
        i: usize,
        qs: &[f32],
        qnorms: &[f64],
        sk: &RealSketch,
        start_c: usize,
        mut acc: DotAcc,
        k: usize,
        top: &[(usize, f64)],
        stats: &mut PruneStats,
    ) -> Option<f64> {
        let v = self.items[i].as_slice();
        let chunk = sk.chunk();
        let n_chunks = sk.n_chunks();
        let mut c = start_c;
        while c < n_chunks {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(self.dim);
            acc.accumulate(&v[lo..hi], &qs[lo..hi]);
            stats.words_streamed += (hi - lo) as u64;
            c += 1;
            if c < n_chunks && top.len() == k {
                let ub = real_upper_bound(acc.value(), sk.rest_norm(i, c - 1) * qnorms[c - 1]);
                let (kj, ks) = top[k - 1];
                if !(ub > ks || (ub == ks && i < kj)) {
                    stats.early_terminated += 1;
                    return None;
                }
            }
        }
        Some(acc.value())
    }

    /// Bound-pruned top-`k`: bit-identical to [`Self::top_k`] while
    /// streaming fewer item elements. `qnorms` and `order` are reusable
    /// scratch buffers (cleared each call).
    pub fn top_k_pruned_with_bufs(
        &self,
        query: &RealHV,
        k: usize,
        stats: &mut PruneStats,
        qnorms: &mut Vec<f64>,
        order: &mut Vec<(f64, DotAcc, u32)>,
    ) -> Vec<(usize, f64)> {
        assert_eq!(query.dim(), self.dim);
        let mut top: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
        if k == 0 || self.items.is_empty() {
            return top;
        }
        let n = self.items.len();
        let qs = query.as_slice();
        stats.items += n as u64;
        stats.words_total += (n * self.dim) as u64;
        if let Some(sk) = &self.sketch {
            let chunk = sk.chunk();
            query_suffix_norms(qs, chunk, qnorms);
            order.clear();
            for i in 0..n {
                let mut dp = DotAcc::new();
                dp.accumulate(sk.prefix_row(i), &qs[..chunk]);
                let ub = real_upper_bound(dp.value(), sk.rest_norm(i, 0) * qnorms[0]);
                order.push((ub, dp, i as u32));
            }
            stats.words_streamed += (n * chunk) as u64;
            // descending upper bound; index breaks ties deterministically
            order.sort_unstable_by(|a, b| {
                b.0.partial_cmp(&a.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.2.cmp(&b.2))
            });
            for pos in 0..order.len() {
                let (ub, dp, iu) = order[pos];
                let i = iu as usize;
                if top.len() == k {
                    let (kj, ks) = top[k - 1];
                    if ub < ks {
                        stats.sketch_rejected += (order.len() - pos) as u64;
                        break;
                    }
                    if !(ub > ks || (ub == ks && i < kj)) {
                        stats.sketch_rejected += 1;
                        continue;
                    }
                }
                if let Some(s) =
                    self.scan_real_item_bounded(i, qs, qnorms, sk, 1, dp, k, &top, stats)
                {
                    if top.len() == k {
                        let (kj, ks) = top[k - 1];
                        if !(s > ks || (s == ks && i < kj)) {
                            continue;
                        }
                    }
                    insert_ranked(&mut top, i, s, k);
                }
            }
        } else {
            // single-chunk rows: no interior boundary to bound across —
            // identical to the exhaustive scan, with streaming accounted
            for (i, it) in self.items.iter().enumerate() {
                let s = it.dot(query);
                stats.words_streamed += self.dim as u64;
                if top.len() == k {
                    let (kj, ks) = top[k - 1];
                    if !(s > ks || (s == ks && i < kj)) {
                        continue;
                    }
                }
                insert_ranked(&mut top, i, s, k);
            }
        }
        top
    }

    /// [`Self::top_k_pruned_with_bufs`] with internal scratch buffers.
    pub fn top_k_pruned(
        &self,
        query: &RealHV,
        k: usize,
        stats: &mut PruneStats,
    ) -> Vec<(usize, f64)> {
        let (mut qnorms, mut order) = (Vec::new(), Vec::new());
        self.top_k_pruned_with_bufs(query, k, stats, &mut qnorms, &mut order)
    }

    /// Bound-pruned nearest: bit-identical to [`Self::nearest`]
    /// (first-wins ties). Drives the same [`Self::scan_real_item_bounded`]
    /// helper as the top-k path over a fixed top-1 slice — zero heap
    /// allocation once the scratch buffers have warmed, so the
    /// resonator's per-factor decode can run inside the allocation-free
    /// `factorize_with` loop.
    pub fn nearest_pruned_with_bufs(
        &self,
        query: &RealHV,
        stats: &mut PruneStats,
        qnorms: &mut Vec<f64>,
        order: &mut Vec<(f64, DotAcc, u32)>,
    ) -> (usize, f64) {
        assert_eq!(query.dim(), self.dim);
        if self.items.is_empty() {
            return (0, f64::NEG_INFINITY);
        }
        let n = self.items.len();
        let qs = query.as_slice();
        stats.items += n as u64;
        stats.words_total += (n * self.dim) as u64;
        let mut top1 = [(0usize, f64::NEG_INFINITY)];
        let mut filled = 0usize;
        if let Some(sk) = &self.sketch {
            let chunk = sk.chunk();
            query_suffix_norms(qs, chunk, qnorms);
            order.clear();
            for i in 0..n {
                let mut dp = DotAcc::new();
                dp.accumulate(sk.prefix_row(i), &qs[..chunk]);
                let ub = real_upper_bound(dp.value(), sk.rest_norm(i, 0) * qnorms[0]);
                order.push((ub, dp, i as u32));
            }
            stats.words_streamed += (n * chunk) as u64;
            order.sort_unstable_by(|a, b| {
                b.0.partial_cmp(&a.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.2.cmp(&b.2))
            });
            for pos in 0..order.len() {
                let (ub, dp, iu) = order[pos];
                let i = iu as usize;
                if filled == 1 {
                    let (bj, bs) = top1[0];
                    if ub < bs {
                        stats.sketch_rejected += (order.len() - pos) as u64;
                        break;
                    }
                    if !(ub > bs || (ub == bs && i < bj)) {
                        stats.sketch_rejected += 1;
                        continue;
                    }
                }
                if let Some(s) =
                    self.scan_real_item_bounded(i, qs, qnorms, sk, 1, dp, 1, &top1[..filled], stats)
                {
                    let (bj, bs) = top1[0];
                    if filled == 1 && !(s > bs || (s == bs && i < bj)) {
                        continue;
                    }
                    top1[0] = (i, s);
                    filled = 1;
                }
            }
        } else {
            for (i, it) in self.items.iter().enumerate() {
                let s = it.dot(query);
                stats.words_streamed += self.dim as u64;
                let (bj, bs) = top1[0];
                if filled == 0 || s > bs || (s == bs && i < bj) {
                    top1[0] = (i, s);
                    filled = 1;
                }
            }
        }
        top1[0]
    }

    /// [`Self::nearest_pruned_with_bufs`] with internal scratch buffers.
    pub fn nearest_pruned(&self, query: &RealHV, stats: &mut PruneStats) -> (usize, f64) {
        let (mut qnorms, mut order) = (Vec::new(), Vec::new());
        self.nearest_pruned_with_bufs(query, stats, &mut qnorms, &mut order)
    }

    /// Batched bound-pruned nearest: result `q` is bit-identical to
    /// [`Self::nearest`]`(&queries[q])`.
    pub fn nearest_batch_pruned_with(
        &self,
        queries: &[RealHV],
        threads: usize,
    ) -> (Vec<(usize, f64)>, PruneStats) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut st = PruneStats::default();
            let (mut qnorms, mut order) = (Vec::new(), Vec::new());
            let out: Vec<(usize, f64)> = queries[r]
                .iter()
                .map(|q| self.nearest_pruned_with_bufs(q, &mut st, &mut qnorms, &mut order))
                .collect();
            (out, st)
        });
        let mut stats = PruneStats::default();
        let mut out = Vec::with_capacity(queries.len());
        for (part, st) in parts {
            out.extend(part);
            stats.merge(&st);
        }
        (out, stats)
    }

    /// Batched bound-pruned top-`k` (see [`Self::top_k_pruned_with_bufs`]).
    pub fn top_k_batch_pruned_with(
        &self,
        queries: &[RealHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, f64)>>, PruneStats) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut st = PruneStats::default();
            let (mut qnorms, mut order) = (Vec::new(), Vec::new());
            let out: Vec<Vec<(usize, f64)>> = queries[r]
                .iter()
                .map(|q| self.top_k_pruned_with_bufs(q, k, &mut st, &mut qnorms, &mut order))
                .collect();
            (out, st)
        });
        let mut stats = PruneStats::default();
        let mut out = Vec::with_capacity(queries.len());
        for (part, st) in parts {
            out.extend(part);
            stats.merge(&st);
        }
        (out, stats)
    }

    /// [`Self::scores`] into a caller-held buffer.
    pub fn scores_into(&self, query: &RealHV, out: &mut Vec<f64>) {
        assert_eq!(query.dim(), self.dim);
        out.clear();
        out.extend(self.items.iter().map(|it| it.dot(query)));
    }

    /// [`Self::scores_batch_with`] into caller-held buffers; see the
    /// binary counterpart for the steady-state allocation contract.
    pub fn scores_batch_into(&self, queries: &[RealHV], threads: usize, out: &mut Vec<Vec<f64>>) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        if threads > 1 && queries.len() > 1 {
            *out = self.scores_batch_with(queries, threads);
            return;
        }
        out.truncate(queries.len());
        while out.len() < queries.len() {
            out.push(Vec::with_capacity(self.items.len()));
        }
        for o in out.iter_mut() {
            o.clear();
        }
        let mut base = 0;
        while base < queries.len() {
            let end = (base + QUERY_BLOCK).min(queries.len());
            for it in &self.items {
                for b in base..end {
                    out[b].push(it.dot(&queries[b]));
                }
            }
            base = end;
        }
    }

    /// Batched dot-product scores, query-blocked (`NSCOG_THREADS` workers).
    pub fn scores_batch(&self, queries: &[RealHV]) -> Vec<Vec<f64>> {
        self.scores_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::scores_batch`] with an explicit worker count.
    pub fn scores_batch_with(&self, queries: &[RealHV], threads: usize) -> Vec<Vec<f64>> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out: Vec<Vec<f64>> = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let base = out.len();
                out.extend(block.iter().map(|_| Vec::with_capacity(self.items.len())));
                for it in &self.items {
                    for (b, q) in block.iter().enumerate() {
                        out[base + b].push(it.dot(q));
                    }
                }
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Batched nearest-item search, equal to per-query [`Self::nearest`].
    pub fn nearest_batch(&self, queries: &[RealHV]) -> Vec<(usize, f64)> {
        self.nearest_batch_with(queries, parallel::configured_threads())
    }

    /// [`Self::nearest_batch`] with an explicit worker count.
    pub fn nearest_batch_with(&self, queries: &[RealHV], threads: usize) -> Vec<(usize, f64)> {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut out = Vec::with_capacity(r.len());
            for block in queries[r].chunks(QUERY_BLOCK) {
                let mut best = vec![(0usize, f64::NEG_INFINITY); block.len()];
                for (i, it) in self.items.iter().enumerate() {
                    for (b, q) in block.iter().enumerate() {
                        let s = it.dot(q);
                        if s > best[b].1 {
                            best[b] = (i, s);
                        }
                    }
                }
                out.extend(best);
            }
            out
        });
        parts.into_iter().flatten().collect()
    }

    /// Fused resonator projection: `scores[k] = item_k · query`, then
    /// `out = sign(Σ_k scores[k] · item_k)` — the paper's d→c→sign chain
    /// in one pass, writing both outputs in place. `scores` keeps its
    /// capacity across calls and `out` is overwritten, so steady-state
    /// sweeps allocate nothing and the intermediate f32 weight vector of
    /// the unfused path disappears.
    pub fn project_signed_into(&self, query: &RealHV, scores: &mut Vec<f64>, out: &mut RealHV) {
        assert_eq!(query.dim(), self.dim);
        assert_eq!(out.dim(), self.dim);
        self.scores_into(query, scores);
        let o = out.as_mut_slice();
        for v in o.iter_mut() {
            *v = 0.0;
        }
        for (&s, item) in scores.iter().zip(&self.items) {
            let w = s as f32;
            if w == 0.0 {
                continue;
            }
            // element-wise accumulate through the dispatched SIMD kernel
            // (bit-identical to the scalar loop on every tier)
            kernels::axpy_f32(o, w, item.as_slice());
        }
        for v in o.iter_mut() {
            *v = if *v >= 0.0 { 1.0 } else { -1.0 };
        }
    }

    /// Probability-weighted bundle: PMF-to-VSA transform (NVSA), routed
    /// through the dispatched `axpy` kernel.
    pub fn weighted_bundle(&self, pmf: &[f64]) -> RealHV {
        assert_eq!(pmf.len(), self.len());
        let mut out = RealHV::zeros(self.dim);
        for (w, item) in pmf.iter().zip(&self.items) {
            kernels::axpy_f32(out.as_mut_slice(), *w as f32, item.as_slice());
        }
        out
    }

    /// VSA-to-PMF transform: ReLU'd similarity, normalized (NVSA).
    pub fn to_pmf(&self, query: &RealHV) -> Vec<f64> {
        let mut scores = self.scores(query);
        relu_normalize(&mut scores);
        scores
    }

    /// Batched [`Self::to_pmf`] through the query-blocked scan: result `q`
    /// equals `to_pmf(&queries[q])`. This is the NVSA decode path's hot
    /// loop (one scan per attribute instead of one per panel).
    pub fn to_pmf_batch(&self, queries: &[RealHV]) -> Vec<Vec<f64>> {
        let mut out = self.scores_batch(queries);
        for scores in &mut out {
            relu_normalize(scores);
        }
        out
    }

    /// ReLU-aware bound-ordered score pass for one query: entries whose
    /// Cauchy–Schwarz upper bound proves a non-positive dot are written
    /// as exactly the `0.0` the ReLU in [`relu_normalize`] would produce,
    /// without streaming their rows; survivors carry the exact canonical
    /// dot. Reuses the PR 3 sketch ordering with the threshold pinned at
    /// zero (a sentinel top-1 entry `(0, 0.0)`), so the sorted tail is
    /// rejected in O(1) the moment a bound drops to ≤ 0 and rows
    /// early-terminate mid-row once `acc + ‖rest‖·‖rest_q‖ ≤ 0`.
    fn scores_relu_pruned_with_bufs(
        &self,
        query: &RealHV,
        stats: &mut PruneStats,
        qnorms: &mut Vec<f64>,
        order: &mut Vec<(f64, DotAcc, u32)>,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(query.dim(), self.dim);
        let n = self.items.len();
        let qs = query.as_slice();
        out.clear();
        out.resize(n, 0.0);
        stats.items += n as u64;
        stats.words_total += (n * self.dim) as u64;
        if let Some(sk) = &self.sketch {
            let chunk = sk.chunk();
            query_suffix_norms(qs, chunk, qnorms);
            order.clear();
            for i in 0..n {
                let mut dp = DotAcc::new();
                dp.accumulate(sk.prefix_row(i), &qs[..chunk]);
                let ub = real_upper_bound(dp.value(), sk.rest_norm(i, 0) * qnorms[0]);
                order.push((ub, dp, i as u32));
            }
            stats.words_streamed += (n * chunk) as u64;
            order.sort_unstable_by(|a, b| {
                b.0.partial_cmp(&a.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.2.cmp(&b.2))
            });
            // the zero threshold as a top-1 sentinel: items survive the
            // shared bound checks only while their bound stays > 0
            let zero_top = [(0usize, 0.0f64)];
            for pos in 0..order.len() {
                let (ub, dp, iu) = order[pos];
                let i = iu as usize;
                if ub <= 0.0 {
                    // sorted order: every later bound is ≤ ub ≤ 0 — the
                    // whole tail ReLUs to zero mass untouched
                    stats.sketch_rejected += (order.len() - pos) as u64;
                    break;
                }
                if let Some(s) =
                    self.scan_real_item_bounded(i, qs, qnorms, sk, 1, dp, 1, &zero_top, stats)
                {
                    out[i] = s;
                }
            }
        } else {
            for (i, it) in self.items.iter().enumerate() {
                out[i] = it.dot(query);
                stats.words_streamed += self.dim as u64;
            }
        }
    }

    /// [`Self::to_pmf_batch`] with ReLU-aware bound pruning: result `q`
    /// equals `to_pmf(&queries[q])` (the only skipped entries are ones
    /// the ReLU provably zeroes, so the normalization mass is untouched)
    /// while streaming fewer item elements when queries anti-correlate
    /// with items — the NVSA decode consumer that only needs the PMF's
    /// positive head. Never streams more than the exhaustive scan.
    pub fn to_pmf_batch_pruned_with(
        &self,
        queries: &[RealHV],
        threads: usize,
    ) -> (Vec<Vec<f64>>, PruneStats) {
        for q in queries {
            assert_eq!(q.dim(), self.dim);
        }
        let parts = parallel::map_ranges(queries.len(), threads, |r| {
            let mut st = PruneStats::default();
            let (mut qnorms, mut order) = (Vec::new(), Vec::new());
            let out: Vec<Vec<f64>> = queries[r]
                .iter()
                .map(|q| {
                    let mut scores = Vec::new();
                    self.scores_relu_pruned_with_bufs(
                        q,
                        &mut st,
                        &mut qnorms,
                        &mut order,
                        &mut scores,
                    );
                    relu_normalize(&mut scores);
                    scores
                })
                .collect();
            (out, st)
        });
        let mut stats = PruneStats::default();
        let mut out = Vec::with_capacity(queries.len());
        for (part, st) in parts {
            out.extend(part);
            stats.merge(&st);
        }
        (out, stats)
    }

    /// f32 storage bytes.
    pub fn storage_bytes(&self) -> usize {
        self.len() * self.dim * 4
    }
}

/// Shared VSA-to-PMF normalization: ReLU then divide by the mass (if any).
fn relu_normalize(scores: &mut [f64]) {
    for s in scores.iter_mut() {
        *s = s.max(0.0);
    }
    let total: f64 = scores.iter().sum();
    if total > 1e-12 {
        for s in scores.iter_mut() {
            *s /= total;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_items_quasi_orthogonal() {
        let mut rng = Rng::new(1);
        let cb = BinaryCodebook::random(&mut rng, 16, 4096);
        for i in 0..16 {
            for j in 0..16 {
                let cos = cb.item(i).cosine(cb.item(j));
                if i == j {
                    assert!((cos - 1.0).abs() < 1e-12);
                } else {
                    assert!(cos.abs() < 0.12, "items {i},{j} cos {cos}");
                }
            }
        }
    }

    #[test]
    fn nearest_recovers_member() {
        let mut rng = Rng::new(2);
        let cb = BinaryCodebook::random(&mut rng, 64, 2048);
        for probe in [0usize, 13, 63] {
            let (idx, score) = cb.nearest(cb.item(probe));
            assert_eq!(idx, probe);
            assert_eq!(score, 2048);
        }
    }

    #[test]
    fn seed_roundtrip_preserves_fold0_and_determinism() {
        let mut rng = Rng::new(3);
        let cb = BinaryCodebook::from_seeds(
            &(0..8)
                .map(|_| (0..8).map(|_| rng.next_u64()).collect::<Vec<u64>>())
                .collect::<Vec<_>>(),
            4096,
        );
        let seeds = cb.seeds();
        let cb2 = BinaryCodebook::from_seeds(&seeds, 4096);
        for i in 0..8 {
            assert_eq!(cb.item(i), cb2.item(i));
        }
    }

    #[test]
    fn compression_ratio() {
        let mut rng = Rng::new(4);
        let cb = BinaryCodebook::random(&mut rng, 32, 8192);
        // 8192/512 = 16x compression from seed-only storage.
        assert_eq!(cb.storage_bytes() / cb.compressed_bytes(), 16);
    }

    #[test]
    fn real_nearest_recovers_member() {
        let mut rng = Rng::new(5);
        let cb = RealCodebook::random_bipolar(&mut rng, 32, 1024);
        let (idx, _) = cb.nearest(cb.item(17));
        assert_eq!(idx, 17);
    }

    #[test]
    fn weighted_bundle_peaks_at_argmax() {
        let mut rng = Rng::new(6);
        let cb = RealCodebook::random_bipolar(&mut rng, 8, 2048);
        let mut pmf = vec![0.02; 8];
        pmf[3] = 0.86;
        let v = cb.weighted_bundle(&pmf);
        let back = cb.to_pmf(&v);
        let argmax = back
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 3);
        assert!((back.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn binary_batch_matches_per_query() {
        let mut rng = Rng::new(8);
        let cb = BinaryCodebook::random(&mut rng, 37, 1024);
        let queries: Vec<BinaryHV> =
            (0..19).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        for threads in [1usize, 2, 5] {
            let nb = cb.nearest_batch_with(&queries, threads);
            let sb = cb.scores_batch_with(&queries, threads);
            for (q, query) in queries.iter().enumerate() {
                assert_eq!(nb[q], cb.nearest(query), "threads={threads} q={q}");
                assert_eq!(sb[q], cb.scores(query), "threads={threads} q={q}");
            }
        }
        assert!(cb.nearest_batch(&[]).is_empty());
    }

    #[test]
    fn real_batch_matches_per_query() {
        let mut rng = Rng::new(9);
        let cb = RealCodebook::random_bipolar(&mut rng, 21, 512);
        let queries: Vec<RealHV> =
            (0..11).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        for threads in [1usize, 3] {
            let nb = cb.nearest_batch_with(&queries, threads);
            let sb = cb.scores_batch_with(&queries, threads);
            for (q, query) in queries.iter().enumerate() {
                assert_eq!(nb[q], cb.nearest(query), "threads={threads} q={q}");
                assert_eq!(sb[q], cb.scores(query), "threads={threads} q={q}");
            }
        }
    }

    #[test]
    fn fused_projection_matches_unfused_chain() {
        use crate::vsa::ops;
        let mut rng = Rng::new(10);
        let cb = RealCodebook::random_bipolar(&mut rng, 12, 512);
        let query = RealHV::random_bipolar(&mut rng, 512);
        let mut scores = Vec::new();
        let mut out = RealHV::zeros(512);
        cb.project_signed_into(&query, &mut scores, &mut out);
        assert_eq!(scores, cb.scores(&query));
        let weights: Vec<f32> = scores.iter().map(|&s| s as f32).collect();
        let items: Vec<&RealHV> = cb.items().iter().collect();
        let expect = ops::weighted_sum(&weights, &items).sign();
        assert_eq!(out, expect);
    }

    /// Oracle: full sort by (score desc, index asc), then truncate.
    fn top_k_oracle<S: Copy + PartialOrd>(scores: &[S], k: usize) -> Vec<(usize, S)> {
        let mut all: Vec<(usize, S)> = scores.iter().copied().enumerate().collect();
        all.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap()
                .then(a.0.cmp(&b.0))
        });
        all.truncate(k);
        all
    }

    #[test]
    fn binary_top_k_matches_sort_oracle() {
        let mut rng = Rng::new(11);
        let cb = BinaryCodebook::random(&mut rng, 33, 512);
        let q = BinaryHV::random(&mut rng, 512);
        let scores = cb.scores(&q);
        for k in [0usize, 1, 3, 33, 50] {
            assert_eq!(cb.top_k(&q, k), top_k_oracle(&scores, k), "k={k}");
        }
        // k=1 agrees with nearest (first-wins ties)
        assert_eq!(cb.top_k(&q, 1)[0], cb.nearest(&q));
        // member query: exact match leads with the full-dim score
        assert_eq!(cb.top_k(cb.item(7), 2)[0], (7, 512));
    }

    #[test]
    fn binary_top_k_tie_prefers_lower_index() {
        // duplicate items force exact score ties
        let mut rng = Rng::new(12);
        let a = BinaryHV::random(&mut rng, 256);
        let b = BinaryHV::random(&mut rng, 256);
        let cb = BinaryCodebook::from_items(256, vec![a.clone(), b.clone(), a.clone()]);
        let top = cb.top_k(&a, 2);
        // indices 0 and 2 tie at the full-dim score: lower index ranks first
        assert_eq!(top[0], (0, 256));
        assert_eq!(top[1], (2, 256));
        assert_eq!(cb.nearest(&a), (0, 256));
        // with room for all three, the weak match comes last
        assert_eq!(cb.top_k(&a, 3)[2].0, 1);
    }

    #[test]
    fn real_top_k_matches_sort_oracle() {
        let mut rng = Rng::new(13);
        let cb = RealCodebook::random_bipolar(&mut rng, 21, 256);
        let q = RealHV::random_bipolar(&mut rng, 256);
        let scores = cb.scores(&q);
        for k in [1usize, 4, 21, 30] {
            assert_eq!(cb.top_k(&q, k), top_k_oracle(&scores, k), "k={k}");
        }
        assert_eq!(cb.top_k(&q, 1)[0], cb.nearest(&q));
    }

    #[test]
    fn from_items_round_trips() {
        let mut rng = Rng::new(14);
        let cb = BinaryCodebook::random(&mut rng, 9, 512);
        let rebuilt = BinaryCodebook::from_items(512, cb.items().to_vec());
        for i in 0..9 {
            assert_eq!(rebuilt.item(i), cb.item(i));
        }
        let rcb = RealCodebook::random_bipolar(&mut rng, 5, 128);
        let rrebuilt = RealCodebook::from_items(128, rcb.items().to_vec());
        assert_eq!(rrebuilt.item(3), rcb.item(3));
    }

    #[test]
    fn binary_pruned_matches_exhaustive_including_ties() {
        let mut rng = Rng::new(20);
        // 2048 bits → default 512-bit sketch active; duplicates force ties
        let a = BinaryHV::random(&mut rng, 2048);
        let b = BinaryHV::random(&mut rng, 2048);
        let mut items = vec![b.clone(), a.clone(), b.clone(), a.clone()];
        items.extend((0..20).map(|_| BinaryHV::random(&mut rng, 2048)));
        let cb = BinaryCodebook::from_items(2048, items);
        assert!(cb.sketch().is_some());
        let mut stats = PruneStats::default();
        for q in [&a, &b, &BinaryHV::random(&mut rng, 2048)] {
            assert_eq!(cb.nearest_pruned(q, &mut stats), cb.nearest(q));
            for k in [1usize, 3, 5, 24, 30] {
                let scores = cb.scores(q);
                assert_eq!(cb.top_k_pruned(q, k, &mut stats), top_k_oracle(&scores, k));
            }
        }
        assert_eq!(stats.items, 18 * 24);
    }

    #[test]
    fn binary_pruned_streams_fewer_words_on_member_queries() {
        let mut rng = Rng::new(21);
        let cb = BinaryCodebook::random(&mut rng, 64, 8192);
        let mut stats = PruneStats::default();
        for i in 0..8 {
            let mut q = cb.item(i * 5).clone();
            for j in rng.sample_indices(8192, 1638) {
                q.set(j, !q.get(j));
            }
            assert_eq!(cb.nearest_pruned(&q, &mut stats), cb.nearest(&q));
        }
        assert!(
            stats.words_streamed < stats.words_total,
            "easy-distribution scans must stream fewer words than exhaustive: {stats:?}"
        );
        assert!(stats.early_terminated > 0 || stats.sketch_rejected > 0);
    }

    #[test]
    fn real_pruned_matches_exhaustive_including_ties() {
        let mut rng = Rng::new(22);
        let base = RealHV::random_bipolar(&mut rng, 1536);
        let mut items = vec![base.clone(), base.clone()];
        items.extend((0..15).map(|_| RealHV::random_bipolar(&mut rng, 1536)));
        let cb = RealCodebook::from_items(1536, items);
        assert!(cb.sketch().is_some());
        let mut stats = PruneStats::default();
        for q in [&base, &RealHV::random_bipolar(&mut rng, 1536)] {
            assert_eq!(cb.nearest_pruned(q, &mut stats), cb.nearest(q));
            let scores = cb.scores(q);
            for k in [1usize, 2, 6, 17, 20] {
                assert_eq!(cb.top_k_pruned(q, k, &mut stats), top_k_oracle(&scores, k));
            }
        }
        // single-chunk rows fall back to the exhaustive-equivalent path
        let small = RealCodebook::random_bipolar(&mut rng, 9, 256);
        assert!(small.sketch().is_none());
        let q = RealHV::random_bipolar(&mut rng, 256);
        assert_eq!(small.nearest_pruned(&q, &mut stats), small.nearest(&q));
        assert_eq!(
            small.top_k_pruned(&q, 4, &mut stats),
            top_k_oracle(&small.scores(&q), 4)
        );
    }

    #[test]
    fn pruned_batches_match_per_query_scans() {
        let mut rng = Rng::new(23);
        let bcb = BinaryCodebook::random(&mut rng, 30, 2048);
        let bqs: Vec<BinaryHV> = (0..9).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
        for threads in [1usize, 3] {
            let (nb, st) = bcb.nearest_batch_pruned_with(&bqs, threads);
            let (tk, _) = bcb.top_k_batch_pruned_with(&bqs, 4, threads);
            assert_eq!(st.items, 9 * 30, "threads={threads}");
            for (q, query) in bqs.iter().enumerate() {
                assert_eq!(nb[q], bcb.nearest(query), "threads={threads} q={q}");
                assert_eq!(tk[q], bcb.top_k(query, 4), "threads={threads} q={q}");
            }
        }
        let rcb = RealCodebook::random_bipolar(&mut rng, 13, 1024);
        let rqs: Vec<RealHV> = (0..7).map(|_| RealHV::random_bipolar(&mut rng, 1024)).collect();
        for threads in [1usize, 2] {
            let (nb, _) = rcb.nearest_batch_pruned_with(&rqs, threads);
            let (tk, _) = rcb.top_k_batch_pruned_with(&rqs, 3, threads);
            for (q, query) in rqs.iter().enumerate() {
                assert_eq!(nb[q], rcb.nearest(query), "threads={threads} q={q}");
                assert_eq!(tk[q], rcb.top_k(query, 3), "threads={threads} q={q}");
            }
        }
    }

    #[test]
    fn rebuild_sketch_honors_width_knob() {
        let mut rng = Rng::new(24);
        let mut cb = BinaryCodebook::random(&mut rng, 12, 4096);
        assert_eq!(cb.sketch().unwrap().bits(), 512);
        cb.rebuild_sketch(1024);
        assert_eq!(cb.sketch().unwrap().bits(), 1024);
        let q = BinaryHV::random(&mut rng, 4096);
        let mut stats = PruneStats::default();
        assert_eq!(cb.top_k_pruned(&q, 3, &mut stats), cb.top_k(&q, 3));
        cb.rebuild_sketch(0);
        assert!(cb.sketch().is_none());
        assert_eq!(cb.top_k_pruned(&q, 3, &mut stats), cb.top_k(&q, 3));
    }

    #[test]
    fn scores_into_reuses_buffers() {
        let mut rng = Rng::new(25);
        let bcb = BinaryCodebook::random(&mut rng, 17, 1024);
        let q = BinaryHV::random(&mut rng, 1024);
        let mut buf = Vec::new();
        bcb.scores_into(&q, &mut buf);
        assert_eq!(buf, bcb.scores(&q));
        let qs: Vec<BinaryHV> = (0..11).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        let mut out = Vec::new();
        for threads in [1usize, 3] {
            bcb.scores_batch_into(&qs, threads, &mut out);
            assert_eq!(out, bcb.scores_batch_with(&qs, 1), "threads={threads}");
        }
        // shrink: a smaller follow-up batch truncates cleanly
        bcb.scores_batch_into(&qs[..4], 1, &mut out);
        assert_eq!(out.len(), 4);
        assert_eq!(out, bcb.scores_batch_with(&qs[..4], 1));
        let rcb = RealCodebook::random_bipolar(&mut rng, 9, 512);
        let rq = RealHV::random_bipolar(&mut rng, 512);
        let mut rbuf = Vec::new();
        rcb.scores_into(&rq, &mut rbuf);
        assert_eq!(rbuf, rcb.scores(&rq));
        let rqs: Vec<RealHV> = (0..5).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        let mut rout = Vec::new();
        rcb.scores_batch_into(&rqs, 1, &mut rout);
        assert_eq!(rout, rcb.scores_batch_with(&rqs, 1));
    }

    #[test]
    fn to_pmf_batch_matches_per_query() {
        let mut rng = Rng::new(15);
        let cb = RealCodebook::random_bipolar(&mut rng, 8, 512);
        let queries: Vec<RealHV> =
            (0..5).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        let batch = cb.to_pmf_batch(&queries);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(batch[q], cb.to_pmf(query), "query {q}");
        }
    }

    #[test]
    fn from_seeds_fused_sketch_equals_item_built_sketch() {
        // the seed-built sidecar must be word-for-word the sidecar an
        // item-prefix build would produce (fold 0 is the seed)
        let mut rng = Rng::new(30);
        let seeds: Vec<Vec<u64>> = (0..9)
            .map(|_| (0..8).map(|_| rng.next_u64()).collect())
            .collect();
        let cb = BinaryCodebook::from_seeds(&seeds, 4096);
        let rebuilt = BinaryCodebook::from_items(4096, cb.items().to_vec());
        let (a, b) = (cb.sketch().unwrap(), rebuilt.sketch().unwrap());
        assert_eq!(a.bits(), b.bits());
        for i in 0..9 {
            assert_eq!(a.row(i), b.row(i), "item {i}");
        }
        // pruned scans over the fused codebook stay bit-identical
        let q = BinaryHV::random(&mut rng, 4096);
        let mut stats = PruneStats::default();
        assert_eq!(cb.top_k_pruned(&q, 3, &mut stats), cb.top_k(&q, 3));
        // a dim short enough for the default sketch to be disabled
        let cb512 = BinaryCodebook::from_seeds(&seeds, 512);
        assert!(cb512.sketch().is_none());
    }

    #[test]
    fn to_pmf_pruned_matches_exhaustive_and_prunes_anticorrelated() {
        let mut rng = Rng::new(31);
        let cb = RealCodebook::random_bipolar(&mut rng, 24, 2048);
        assert!(cb.sketch().is_some());
        // mix: random, member, and negated members (anti-correlated: the
        // distribution where the ReLU bound actually pays)
        let mut queries: Vec<RealHV> = vec![
            RealHV::random_bipolar(&mut rng, 2048),
            cb.item(3).clone(),
        ];
        for i in 0..6 {
            let mut neg = cb.item(i * 4).clone();
            for v in neg.as_mut_slice().iter_mut() {
                *v = -*v;
            }
            queries.push(neg);
        }
        for threads in [1usize, 3] {
            let (batch, stats) = cb.to_pmf_batch_pruned_with(&queries, threads);
            for (q, query) in queries.iter().enumerate() {
                assert_eq!(batch[q], cb.to_pmf(query), "threads={threads} q={q}");
            }
            assert_eq!(stats.items, queries.len() as u64 * 24);
            assert!(
                stats.words_streamed <= stats.words_total,
                "relu-pruned scan streamed beyond exhaustive: {stats:?}"
            );
            assert!(
                stats.early_terminated + stats.sketch_rejected > 0,
                "negated-member queries must prune: {stats:?}"
            );
        }
        // single-chunk rows fall back to the exhaustive-equivalent path
        let small = RealCodebook::random_bipolar(&mut rng, 7, 256);
        assert!(small.sketch().is_none());
        let qs = vec![RealHV::random_bipolar(&mut rng, 256)];
        let (batch, _) = small.to_pmf_batch_pruned_with(&qs, 1);
        assert_eq!(batch[0], small.to_pmf(&qs[0]));
    }

    #[test]
    fn ca90_backing_matches_ram_twin_bit_for_bit() {
        let mut rng = Rng::new(40);
        let seeds: Vec<Vec<u64>> = (0..21)
            .map(|_| (0..8).map(|_| rng.next_u64()).collect())
            .collect();
        let ca = BinaryCodebook::ca90_from_seeds(&seeds, 4096, Some(512));
        assert!(ca.is_ca90());
        assert_eq!(ca.backing_name(), "ca90");
        assert_eq!(ca.len(), 21);
        let ram = ca.materialized();
        assert!(!ram.is_ca90());
        assert_eq!(ram.len(), 21);
        // seeds survive the round trip in both directions
        assert_eq!(ca.seeds(), ram.seeds());
        for i in 0..21 {
            assert_eq!(ca.materialize_item(i), *ram.item(i), "item {i}");
        }
        // rows only resident as seeds: 8x smaller at 4096/512
        assert_eq!(ca.row_resident_bytes() * 8, ram.row_resident_bytes());
        // every scan entry point agrees bit-for-bit across backings
        let mut queries: Vec<BinaryHV> =
            (0..5).map(|_| BinaryHV::random(&mut rng, 4096)).collect();
        queries.push(ram.item(13).clone()); // member query exercises pruning
        let mut st_ca = PruneStats::default();
        let mut st_ram = PruneStats::default();
        for q in &queries {
            assert_eq!(ca.nearest(q), ram.nearest(q));
            assert_eq!(ca.scores(q), ram.scores(q));
            for k in [1usize, 4, 21, 30] {
                assert_eq!(ca.top_k(q, k), ram.top_k(q, k), "k={k}");
                assert_eq!(
                    ca.top_k_pruned(q, k, &mut st_ca),
                    ram.top_k_pruned(q, k, &mut st_ram),
                    "k={k}"
                );
            }
            assert_eq!(ca.nearest_pruned(q, &mut st_ca), ram.nearest_pruned(q, &mut st_ram));
        }
        assert_eq!(st_ca.items, st_ram.items);
        for threads in [1usize, 3] {
            assert_eq!(
                ca.nearest_batch_with(&queries, threads),
                ram.nearest_batch_with(&queries, threads)
            );
            assert_eq!(
                ca.scores_batch_with(&queries, threads),
                ram.scores_batch_with(&queries, threads)
            );
            let (na, _) = ca.nearest_batch_pruned_with(&queries, threads);
            let (nr, _) = ram.nearest_batch_pruned_with(&queries, threads);
            assert_eq!(na, nr);
        }
        // no-sketch ca90 codebooks run the exhaustive-equivalent path
        let bare = BinaryCodebook::ca90_from_seeds(&seeds, 1024, None);
        assert!(bare.sketch().is_none());
        let q = BinaryHV::random(&mut rng, 1024);
        let mut st = PruneStats::default();
        let twin = bare.materialized();
        assert_eq!(bare.top_k_pruned(&q, 5, &mut st), twin.top_k(&q, 5));
    }

    #[test]
    #[should_panic(expected = "ca90 backing requires dim")]
    fn ca90_backing_rejects_unaligned_dim() {
        let seeds = vec![vec![1u64; 8]];
        BinaryCodebook::ca90_from_seeds(&seeds, 576, None);
    }

    #[test]
    #[should_panic(expected = "seeds only")]
    fn ca90_backing_item_access_panics() {
        let seeds = vec![vec![1u64; 8]];
        let cb = BinaryCodebook::ca90_from_seeds(&seeds, 1024, None);
        let _ = cb.item(0);
    }

    #[test]
    fn cascade_pruned_matches_exhaustive_and_bulk_rejects() {
        let mut rng = Rng::new(41);
        // duplicates + member queries: ties and heavy pruning together
        let a = BinaryHV::random(&mut rng, 8192);
        let mut items = vec![a.clone(), a.clone()];
        items.extend((0..62).map(|_| BinaryHV::random(&mut rng, 8192)));
        let mut cb = BinaryCodebook::from_items(8192, items);
        assert!(cb.enable_cascade(128));
        assert_eq!(cb.sketch().unwrap().coarse_bits(), 128);
        let mut stats = PruneStats::default();
        let queries = [a.clone(), BinaryHV::random(&mut rng, 8192)];
        for q in &queries {
            assert_eq!(cb.nearest_pruned(q, &mut stats), cb.nearest(q));
            let scores = cb.scores(q);
            for k in [1usize, 2, 7, 64, 80] {
                assert_eq!(
                    cb.top_k_pruned(q, k, &mut stats),
                    top_k_oracle(&scores, k),
                    "k={k}"
                );
            }
        }
        assert!(
            stats.coarse_rejected > 0,
            "member queries must bulk-reject on the coarse level: {stats:?}"
        );
        assert!(stats.words_streamed <= stats.words_total);
        // ca90 backing composes with the cascade
        let mut ca = BinaryCodebook::ca90_from_seeds(&cb.seeds(), 8192, Some(512));
        assert!(ca.enable_cascade(128));
        let twin = ca.materialized();
        assert_eq!(twin.sketch().unwrap().coarse_bits(), 128);
        let mut st = PruneStats::default();
        for q in &queries {
            // note: seeds() of the duplicate-item book regenerates
            // different rows (fold 0 only survives), so oracle against
            // the ca90 book's own materialized twin
            assert_eq!(
                ca.top_k_pruned(q, 5, &mut st),
                top_k_oracle(&twin.scores(q), 5)
            );
        }
    }

    #[test]
    fn cascade_strictly_reduces_prefilter_words_on_easy_queries() {
        let mut rng = Rng::new(42);
        let cb_plain = BinaryCodebook::random(&mut rng, 96, 8192);
        let mut cb_casc = BinaryCodebook::from_items(8192, cb_plain.items().to_vec());
        assert!(cb_casc.enable_cascade(128));
        let mut near = cb_plain.item(11).clone();
        for j in rng.sample_indices(8192, 400) {
            near.set(j, !near.get(j));
        }
        let mut st_plain = PruneStats::default();
        let mut st_casc = PruneStats::default();
        assert_eq!(
            cb_casc.top_k_pruned(&near, 3, &mut st_casc),
            cb_plain.top_k_pruned(&near, 3, &mut st_plain)
        );
        assert!(
            st_casc.words_streamed < st_plain.words_streamed,
            "cascade must stream fewer words than single-level sketch: \
             cascade {} vs plain {}",
            st_casc.words_streamed,
            st_plain.words_streamed
        );
    }

    #[test]
    fn resident_bytes_accounts_rows_and_sidecars() {
        let mut rng = Rng::new(43);
        let mut cb = BinaryCodebook::random(&mut rng, 10, 4096);
        let rows = 10 * 4096 / 8;
        let sketch = 10 * 512 / 8;
        assert_eq!(cb.resident_bytes(), rows + sketch);
        assert!(cb.enable_cascade(128));
        assert_eq!(cb.resident_bytes(), rows + sketch + 10 * 128 / 8);
        let ca = BinaryCodebook::ca90_from_seeds(&cb.seeds(), 4096, Some(512));
        assert_eq!(ca.row_resident_bytes(), 10 * 512 / 8);
        assert_eq!(ca.resident_bytes(), 10 * 512 / 8 + sketch);
    }

    #[test]
    fn to_pmf_of_orthogonal_query_is_spread() {
        let mut rng = Rng::new(7);
        let cb = RealCodebook::random_bipolar(&mut rng, 8, 2048);
        let q = RealHV::random_bipolar(&mut rng, 2048);
        let pmf = cb.to_pmf(&q);
        assert!(pmf.iter().all(|&p| p < 0.9));
    }
}
