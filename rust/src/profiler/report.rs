//! Characterization report assembly: the full per-workload summary that
//! the `characterize` example and the figure benches print.

use super::memstat::MemoryStats;
use super::roofline::RooflinePoint;
use super::sparsity::SparsityPoint;
use super::taxonomy::PhaseKind;
use super::trace::Trace;
use crate::platform::{Platform, TimeBreakdown};

/// Full characterization of one workload on one platform.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub workload: String,
    pub platform: &'static str,
    pub breakdown: TimeBreakdown,
    pub neural_breakdown: TimeBreakdown,
    pub symbolic_breakdown: TimeBreakdown,
    pub memory: MemoryStats,
    pub roofline: Vec<RooflinePoint>,
    pub sparsity: Vec<SparsityPoint>,
    pub n_ops: usize,
}

impl WorkloadReport {
    /// Build a report from a trace + memory stats on a platform.
    pub fn build(
        trace: &Trace,
        memory: MemoryStats,
        sparsity: Vec<SparsityPoint>,
        platform: &Platform,
    ) -> WorkloadReport {
        let breakdown = platform.trace_time(trace, None);
        let neural_breakdown = platform.trace_time(trace, Some(PhaseKind::Neural));
        let symbolic_breakdown = platform.trace_time(trace, Some(PhaseKind::Symbolic));
        let roofline = vec![
            super::roofline::place(trace, PhaseKind::Neural, platform),
            super::roofline::place(trace, PhaseKind::Symbolic, platform),
        ];
        WorkloadReport {
            workload: trace.workload.clone(),
            platform: platform.name,
            breakdown,
            neural_breakdown,
            symbolic_breakdown,
            memory,
            roofline,
            sparsity,
            n_ops: trace.len(),
        }
    }

    /// One-line summary (workload, total time, symbolic %).
    pub fn summary_line(&self) -> String {
        format!(
            "{:<8} {:>10} total  neural {:>5.1}%  symbolic {:>5.1}%  ({} ops)",
            self.workload,
            crate::util::stats::fmt_time(self.breakdown.total),
            (1.0 - self.breakdown.symbolic_fraction()) * 100.0,
            self.breakdown.symbolic_fraction() * 100.0,
            self.n_ops,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::taxonomy::OpCategory;

    #[test]
    fn report_assembles() {
        let mut tr = Trace::new("TEST");
        tr.add("gemm", OpCategory::MatMul, PhaseKind::Neural, 1 << 28, 1 << 20, 1 << 20, &[]);
        tr.add("bind", OpCategory::VectorElem, PhaseKind::Symbolic, 1 << 18, 1 << 24, 1 << 24, &[]);
        let r = WorkloadReport::build(
            &tr,
            MemoryStats::default(),
            vec![],
            &Platform::rtx2080ti(),
        );
        assert_eq!(r.workload, "TEST");
        assert_eq!(r.roofline.len(), 2);
        assert!(r.breakdown.total > 0.0);
        assert!(r.summary_line().contains("TEST"));
        // symbolic streaming phase should be memory-bound
        assert!(r.roofline[1].memory_bound);
    }
}
