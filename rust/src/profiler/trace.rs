//! Operator traces: the unit of workload characterization.
//!
//! Every workload model (`crate::workloads`) emits its compute graph as a
//! [`Trace`] of [`OpRecord`]s — category, phase, FLOPs, bytes moved,
//! output sparsity, and dependency edges.  Platform cost models map
//! traces to time/energy (Figs. 2, 3, 11b; Tab. IV) and the coordinator
//! derives critical paths from the dependency edges (Fig. 4).

use super::taxonomy::{OpCategory, PhaseKind};

/// One profiled operator instance.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub name: String,
    pub category: OpCategory,
    pub phase: PhaseKind,
    /// Floating-point (or integer-ALU) operations.
    pub flops: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Fraction of zeros in the operator's output (0.0 = dense).
    pub output_sparsity: f64,
    /// Indices of trace ops this op consumes (dependency edges).
    pub deps: Vec<usize>,
}

impl OpRecord {
    /// Total bytes moved.
    pub fn bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Operational intensity (FLOPs per byte) — the roofline x-axis.
    pub fn intensity(&self) -> f64 {
        self.flops as f64 / self.bytes().max(1) as f64
    }
}

/// A workload's operator trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub workload: String,
    pub ops: Vec<OpRecord>,
}

impl Trace {
    pub fn new(workload: impl Into<String>) -> Self {
        Trace {
            workload: workload.into(),
            ops: Vec::new(),
        }
    }

    /// Append an operator; returns its index (for dependency wiring).
    #[allow(clippy::too_many_arguments)]
    pub fn add(
        &mut self,
        name: impl Into<String>,
        category: OpCategory,
        phase: PhaseKind,
        flops: u64,
        bytes_read: u64,
        bytes_written: u64,
        deps: &[usize],
    ) -> usize {
        self.ops.push(OpRecord {
            name: name.into(),
            category,
            phase,
            flops,
            bytes_read,
            bytes_written,
            output_sparsity: 0.0,
            deps: deps.to_vec(),
        });
        self.ops.len() - 1
    }

    /// One-operator trace — the shape the serve engine's measured
    /// roofline bridge emits per `(store, request class)` kernel
    /// aggregate before handing it to
    /// [`crate::profiler::roofline::place`].
    #[allow(clippy::too_many_arguments)]
    pub fn single(
        workload: impl Into<String>,
        name: impl Into<String>,
        category: OpCategory,
        phase: PhaseKind,
        flops: u64,
        bytes_read: u64,
        bytes_written: u64,
    ) -> Trace {
        let mut tr = Trace::new(workload);
        tr.add(name, category, phase, flops, bytes_read, bytes_written, &[]);
        tr
    }

    /// Set the output sparsity of op `idx`.
    pub fn set_sparsity(&mut self, idx: usize, s: f64) {
        self.ops[idx].output_sparsity = s.clamp(0.0, 1.0);
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total FLOPs in a phase.
    pub fn flops(&self, phase: Option<PhaseKind>) -> u64 {
        self.ops
            .iter()
            .filter(|o| phase.map_or(true, |p| o.phase == p))
            .map(|o| o.flops)
            .sum()
    }

    /// Total bytes in a phase.
    pub fn bytes(&self, phase: Option<PhaseKind>) -> u64 {
        self.ops
            .iter()
            .filter(|o| phase.map_or(true, |p| o.phase == p))
            .map(|o| o.bytes())
            .sum()
    }

    /// Ops filtered by (phase, category).
    pub fn select(
        &self,
        phase: Option<PhaseKind>,
        category: Option<OpCategory>,
    ) -> impl Iterator<Item = &OpRecord> {
        self.ops.iter().filter(move |o| {
            phase.map_or(true, |p| o.phase == p)
                && category.map_or(true, |c| o.category == c)
        })
    }

    /// Validate dependency indices are acyclic (forward-only) and in
    /// range. Traces are built in topological order by construction.
    pub fn validate(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            for &d in &op.deps {
                if d >= i {
                    return Err(format!(
                        "op {i} ({}) depends on {d} which is not earlier",
                        op.name
                    ));
                }
            }
        }
        Ok(())
    }

    /// Mean output sparsity over symbolic ops (Fig. 5 headline number).
    pub fn mean_sparsity(&self, phase: PhaseKind) -> f64 {
        let sel: Vec<f64> = self
            .ops
            .iter()
            .filter(|o| o.phase == phase)
            .map(|o| o.output_sparsity)
            .collect();
        if sel.is_empty() {
            0.0
        } else {
            sel.iter().sum::<f64>() / sel.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Trace {
        let mut tr = Trace::new("test");
        let a = tr.add("conv1", OpCategory::Conv, PhaseKind::Neural, 1000, 100, 50, &[]);
        let b = tr.add("bind", OpCategory::VectorElem, PhaseKind::Symbolic, 10, 80, 80, &[a]);
        tr.add("search", OpCategory::VectorElem, PhaseKind::Symbolic, 20, 160, 8, &[b]);
        tr
    }

    #[test]
    fn totals_by_phase() {
        let tr = t();
        assert_eq!(tr.flops(Some(PhaseKind::Neural)), 1000);
        assert_eq!(tr.flops(Some(PhaseKind::Symbolic)), 30);
        assert_eq!(tr.bytes(None), 150 + 160 + 168);
    }

    #[test]
    fn intensity() {
        let tr = t();
        assert!((tr.ops[0].intensity() - 1000.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn single_op_trace_round_trips() {
        let tr = Trace::single(
            "serve:recall",
            "cleanup_scan",
            OpCategory::VectorElem,
            PhaseKind::Symbolic,
            30,
            80,
            16,
        );
        assert_eq!(tr.len(), 1);
        assert_eq!(tr.flops(Some(PhaseKind::Symbolic)), 30);
        assert_eq!(tr.bytes(None), 96);
        assert!(tr.validate().is_ok());
    }

    #[test]
    fn validates_topological_deps() {
        let tr = t();
        assert!(tr.validate().is_ok());
        let mut bad = Trace::new("bad");
        bad.add("x", OpCategory::Other, PhaseKind::Symbolic, 1, 1, 1, &[]);
        bad.ops[0].deps.push(5);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn sparsity_accounting() {
        let mut tr = t();
        tr.set_sparsity(1, 0.96);
        tr.set_sparsity(2, 0.98);
        assert!((tr.mean_sparsity(PhaseKind::Symbolic) - 0.97).abs() < 1e-12);
        assert_eq!(tr.mean_sparsity(PhaseKind::Neural), 0.0);
    }

    #[test]
    fn select_filters() {
        let tr = t();
        assert_eq!(tr.select(Some(PhaseKind::Symbolic), None).count(), 2);
        assert_eq!(
            tr.select(None, Some(OpCategory::Conv)).count(),
            1
        );
    }
}
