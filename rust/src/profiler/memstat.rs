//! Memory accounting (Fig. 3b + Takeaway 4): storage footprint (weights,
//! codebooks) and peak intermediate ("working set") memory per phase.

use super::taxonomy::PhaseKind;
use super::trace::Trace;

/// Memory breakdown for one workload.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryStats {
    /// Neural weight storage (bytes).
    pub weights_bytes: u64,
    /// Symbolic codebook / knowledge-base storage (bytes).
    pub codebook_bytes: u64,
    /// Peak intermediate bytes during the neural phase.
    pub neural_working_bytes: u64,
    /// Peak intermediate bytes during the symbolic phase.
    pub symbolic_working_bytes: u64,
}

impl MemoryStats {
    pub fn storage_total(&self) -> u64 {
        self.weights_bytes + self.codebook_bytes
    }

    pub fn working_total(&self) -> u64 {
        self.neural_working_bytes + self.symbolic_working_bytes
    }

    /// Fraction of storage taken by weights + codebooks (paper: >90% for
    /// NVSA).
    pub fn static_fraction(&self) -> f64 {
        let total = self.storage_total() + self.working_total();
        if total == 0 {
            return 0.0;
        }
        self.storage_total() as f64 / total as f64
    }
}

/// Estimate working-set peaks from a trace: the max bytes written by any
/// single op plus its read set (a simple live-range-free proxy that
/// tracks the paper's "large intermediate caching" observation).
pub fn working_set(trace: &Trace, phase: PhaseKind) -> u64 {
    trace
        .ops
        .iter()
        .filter(|o| o.phase == phase)
        .map(|o| o.bytes_read + o.bytes_written)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::taxonomy::OpCategory;

    #[test]
    fn static_fraction() {
        let m = MemoryStats {
            weights_bytes: 900,
            codebook_bytes: 50,
            neural_working_bytes: 30,
            symbolic_working_bytes: 20,
        };
        assert!((m.static_fraction() - 0.95).abs() < 1e-12);
        assert_eq!(m.storage_total(), 950);
    }

    #[test]
    fn working_set_takes_max_op() {
        let mut tr = Trace::new("x");
        tr.add("a", OpCategory::VectorElem, PhaseKind::Symbolic, 1, 100, 20, &[]);
        tr.add("b", OpCategory::VectorElem, PhaseKind::Symbolic, 1, 400, 80, &[]);
        tr.add("n", OpCategory::Conv, PhaseKind::Neural, 1, 999, 1, &[]);
        assert_eq!(working_set(&tr, PhaseKind::Symbolic), 480);
        assert_eq!(working_set(&tr, PhaseKind::Neural), 1000);
    }
}
