//! The paper's six-way compute-operator taxonomy (Sec. IV-B) and the
//! neural/symbolic phase split.

/// Operator category (Sec. IV-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpCategory {
    /// Kernel-sliding convolutions (neural perception).
    Conv,
    /// Dense or sparse GEMM (fully-connected layers, projections).
    MatMul,
    /// Vector / element-wise tensor ops (add, mul, activation,
    /// normalization, relational) — the dominant symbolic class.
    VectorElem,
    /// Reshapes, transposes, masked selection, coalescing.
    DataTransform,
    /// Memory↔compute, host↔device transfers, duplication, assignment.
    DataMovement,
    /// Fuzzy first-order logic, logic rules, graph/control operations.
    Other,
}

impl OpCategory {
    pub const ALL: [OpCategory; 6] = [
        OpCategory::Conv,
        OpCategory::MatMul,
        OpCategory::VectorElem,
        OpCategory::DataTransform,
        OpCategory::DataMovement,
        OpCategory::Other,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            OpCategory::Conv => "Conv",
            OpCategory::MatMul => "MatMul",
            OpCategory::VectorElem => "Vector/Elem",
            OpCategory::DataTransform => "DataTransform",
            OpCategory::DataMovement => "DataMovement",
            OpCategory::Other => "Other",
        }
    }
}

/// Which side of the neuro-symbolic split an operation belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PhaseKind {
    Neural,
    Symbolic,
}

impl PhaseKind {
    pub fn label(&self) -> &'static str {
        match self {
            PhaseKind::Neural => "neural",
            PhaseKind::Symbolic => "symbolic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_categories() {
        assert_eq!(OpCategory::ALL.len(), 6);
        let labels: Vec<_> = OpCategory::ALL.iter().map(|c| c.label()).collect();
        assert!(labels.contains(&"MatMul"));
        assert!(labels.contains(&"Vector/Elem"));
    }
}
