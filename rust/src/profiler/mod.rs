//! Workload characterization: operator taxonomy (Sec. IV-B), trace
//! collection, roofline analysis (Fig. 3c), memory accounting (Fig. 3b),
//! and sparsity measurement (Fig. 5).

pub mod memstat;
pub mod report;
pub mod roofline;
pub mod sparsity;
pub mod taxonomy;
pub mod trace;

pub use taxonomy::{OpCategory, PhaseKind};
pub use trace::{OpRecord, Trace};
