//! Sparsity measurement (Fig. 5): measured zero fractions of real tensor
//! data flowing through the symbolic engines.

/// Fraction of near-zero entries in a slice.
pub fn sparsity_of(xs: &[f32], eps: f32) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|x| x.abs() < eps).count() as f64 / xs.len() as f64
}

/// Fraction of exactly-zero entries of an f64 slice.
pub fn sparsity_f64(xs: &[f64], eps: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|x| x.abs() < eps).count() as f64 / xs.len() as f64
}

/// A named sparsity measurement (one bar of Fig. 5).
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityPoint {
    /// Symbolic module ("pmf_to_vsa", "prob_compute", "vsa_to_pmf").
    pub module: String,
    /// Task attribute ("type", "size", "color").
    pub attribute: String,
    pub sparsity: f64,
}

/// Classify a sparsity pattern as structured (contiguous zero runs) or
/// unstructured. The paper observes *unstructured* patterns; this check
/// backs that claim on our measured data.
pub fn is_structured(mask: &[bool], min_run: usize) -> bool {
    // structured if >=80% of zeros sit in runs of at least `min_run`
    let zeros = mask.iter().filter(|&&z| z).count();
    if zeros == 0 {
        return false;
    }
    let mut in_runs = 0usize;
    let mut run = 0usize;
    for &z in mask.iter().chain(std::iter::once(&false)) {
        if z {
            run += 1;
        } else {
            if run >= min_run {
                in_runs += run;
            }
            run = 0;
        }
    }
    in_runs as f64 / zeros as f64 >= 0.8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn counts_zero_fraction() {
        assert!((sparsity_of(&[0.0, 1.0, 0.0, 0.0], 1e-9) - 0.75).abs() < 1e-12);
        assert_eq!(sparsity_of(&[], 1e-9), 0.0);
    }

    #[test]
    fn eps_threshold() {
        assert!((sparsity_of(&[1e-8, 1.0], 1e-6) - 0.5).abs() < 1e-12);
        assert_eq!(sparsity_of(&[1e-8, 1.0], 1e-9), 0.0);
    }

    #[test]
    fn structured_detection() {
        let mut structured = vec![false; 100];
        for z in structured.iter_mut().take(60) {
            *z = true;
        }
        assert!(is_structured(&structured, 8));

        let mut rng = Rng::new(1);
        let random: Vec<bool> = (0..100).map(|_| rng.chance(0.6)).collect();
        assert!(!is_structured(&random, 8));
    }
}
