//! Roofline analysis (Fig. 3c): place each workload component on the
//! (operational intensity, attained throughput) plane of a platform and
//! classify it as memory- or compute-bound.

use super::taxonomy::PhaseKind;
use super::trace::Trace;
use crate::platform::Platform;

/// One roofline point.
#[derive(Debug, Clone, PartialEq)]
pub struct RooflinePoint {
    pub workload: String,
    pub phase: PhaseKind,
    /// FLOPs per byte.
    pub intensity: f64,
    /// Attained FLOP/s under the platform model.
    pub attained_flops: f64,
    /// True if the point sits left of the platform's ridge point.
    pub memory_bound: bool,
}

/// Compute the ridge point (intensity where compute roof meets memory
/// roof) of a platform.
pub fn ridge_intensity(p: &Platform) -> f64 {
    p.peak_flops / p.dram_bw
}

/// Place one phase of a trace on the roofline.
pub fn place(trace: &Trace, phase: PhaseKind, platform: &Platform) -> RooflinePoint {
    let flops = trace.flops(Some(phase)) as f64;
    let bytes = trace.bytes(Some(phase)).max(1) as f64;
    let intensity = flops / bytes;
    let time = platform.trace_time(trace, Some(phase)).total;
    let attained = if time > 0.0 { flops / time } else { 0.0 };
    RooflinePoint {
        workload: trace.workload.clone(),
        phase,
        intensity,
        attained_flops: attained,
        memory_bound: intensity < ridge_intensity(platform),
    }
}

/// Roofline model ceiling at a given intensity.
pub fn roof(p: &Platform, intensity: f64) -> f64 {
    (intensity * p.dram_bw).min(p.peak_flops)
}

/// Place one *measured* kernel aggregate on a platform's roofline: the
/// intensity and attained FLOP/s come from live counters (FLOPs, bytes,
/// measured wall time inside the kernel calls) instead of the
/// analytical cost model — the serve engine's roofline bridge
/// (`serve-bench --trace`) feeds its per-`(store, class)`
/// [`crate::serve::KernelWork`] through here. The memory-/compute-bound
/// verdict compares the measured intensity against the same ridge point
/// as [`place`], so modelled and measured points share one axis system.
pub fn place_measured(
    workload: &str,
    phase: PhaseKind,
    flops: u64,
    bytes: u64,
    elapsed_s: f64,
    platform: &Platform,
) -> RooflinePoint {
    let intensity = flops as f64 / bytes.max(1) as f64;
    RooflinePoint {
        workload: workload.to_string(),
        phase,
        intensity,
        attained_flops: if elapsed_s > 0.0 {
            flops as f64 / elapsed_s
        } else {
            0.0
        },
        memory_bound: intensity < ridge_intensity(platform),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;
    use crate::profiler::taxonomy::OpCategory;

    #[test]
    fn ridge_point_sane() {
        let p = Platform::rtx2080ti();
        let r = ridge_intensity(&p);
        // 13.45 TFLOPs / 616 GB/s ≈ 21.8 FLOP/byte
        assert!((10.0..40.0).contains(&r), "ridge {r}");
    }

    #[test]
    fn roof_is_min_of_two_ceilings() {
        let p = Platform::rtx2080ti();
        assert!(roof(&p, 0.1) < p.peak_flops);
        assert!((roof(&p, 1e6) - p.peak_flops).abs() < 1.0);
    }

    #[test]
    fn symbolic_streaming_is_memory_bound() {
        let p = Platform::rtx2080ti();
        let mut tr = Trace::new("x");
        // streaming elementwise: 1 FLOP per 8 bytes
        tr.add("bind", OpCategory::VectorElem, PhaseKind::Symbolic, 1_000_000, 4_000_000, 4_000_000, &[]);
        let pt = place(&tr, PhaseKind::Symbolic, &p);
        assert!(pt.memory_bound);
        assert!(pt.intensity < 1.0);
    }

    #[test]
    fn measured_placement_uses_live_counters_and_shared_ridge() {
        let p = Platform::host();
        // binary cleanup scan shape: 3 ops per u64 word streamed →
        // intensity 3/8 FLOP/byte, far left of any CPU ridge
        let pt = place_measured("recall", PhaseKind::Symbolic, 3_000_000, 8_000_000, 1e-3, &p);
        assert!(pt.memory_bound);
        assert!((pt.intensity - 0.375).abs() < 1e-12);
        assert!((pt.attained_flops - 3.0e9).abs() < 1.0);
        // zero elapsed (no traffic) degrades to zero attained, no panic
        let idle = place_measured("idle", PhaseKind::Symbolic, 0, 0, 0.0, &p);
        assert_eq!(idle.attained_flops, 0.0);
    }

    #[test]
    fn dense_matmul_is_compute_bound() {
        let p = Platform::rtx2080ti();
        let mut tr = Trace::new("x");
        // 1024^3 GEMM: 2*N^3 flops, 3*N^2*4 bytes
        let n = 1024u64;
        tr.add("gemm", OpCategory::MatMul, PhaseKind::Neural, 2 * n * n * n, 8 * n * n, 4 * n * n, &[]);
        let pt = place(&tr, PhaseKind::Neural, &p);
        assert!(!pt.memory_bound, "intensity {}", pt.intensity);
    }
}
