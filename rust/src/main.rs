//! `nscog` — CLI for the neuro-symbolic workload characterization & VSA
//! accelerator reproduction (Wan et al., 2024).
//!
//! Subcommands:
//!   figures                regenerate every paper table/figure
//!   characterize [NAME]    per-workload characterization report
//!   accel [CFG] [WORKLOAD] run a suite workload on the simulator
//!   solve [--grid G]       solve synthetic RPM instances with NVSA+PrAE
//!   serve-bench [FLAGS]    load-test the batched serving engine
//!   serve [--listen ADDR]  expose the engine on a TCP socket (framed wire)
//!   runtime-info           check PJRT artifacts
//!   info                   print system inventory

use nscog::accel::isa::ControlMethod;
use nscog::accel::AccelConfig;
use nscog::platform::Platform;
use nscog::profiler::report::WorkloadReport;
use nscog::util::stats::{fmt_bytes, fmt_time};
use nscog::workloads::suite::{CompiledSuite, SuiteKind};
use nscog::workloads::{all_workloads, raven};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("info");
    match cmd {
        "figures" => figures(),
        "characterize" => characterize(args.get(1).map(String::as_str)),
        "accel" => accel(
            args.get(1).map(String::as_str).unwrap_or("acc4"),
            args.get(2).map(String::as_str).unwrap_or("fact"),
        ),
        "solve" => solve(
            args.iter()
                .position(|a| a == "--grid")
                .and_then(|i| args.get(i + 1))
                .and_then(|g| g.parse().ok())
                .unwrap_or(3),
        ),
        "serve-bench" => serve_bench(&args[1..]),
        "serve" => serve(&args[1..]),
        "runtime-info" => runtime_info(),
        "info" | "--help" | "-h" => info(),
        other => {
            eprintln!("unknown subcommand '{other}'");
            info();
            std::process::exit(2);
        }
    }
}

fn info() {
    use nscog::vsa::kernels;
    println!("nscog — neuro-symbolic workload characterization & VSA accelerator");
    println!("reproduction of Wan et al., 'Towards Efficient Neuro-Symbolic AI' (2024)\n");
    let avail: Vec<&str> = kernels::available_tiers().iter().map(|t| t.name()).collect();
    let avx512_note = if !kernels::avx512_popcnt_available() {
        ""
    } else if kernels::active_tier() == nscog::vsa::SimdTier::Avx2 {
        "; avx512vpopcntdq detected, routed via avx2 kernels"
    } else {
        "; avx512vpopcntdq detected"
    };
    println!(
        "simd: dispatch tier '{}' (available: {}{}) — override: NSCOG_SIMD=scalar|avx2|neon|auto",
        kernels::active_tier().name(),
        avail.join(", "),
        avx512_note
    );
    println!();
    println!("subcommands:");
    println!("  figures               regenerate every paper table/figure");
    println!("  characterize [NAME]   characterization report (LNN/LTN/NVSA/NLM/VSAIT/ZeroC/PrAE)");
    println!("  accel [acc2|acc4|acc8] [mult|tree|fact|react]");
    println!("  solve [--grid 2|3]    solve synthetic RPM with NVSA + PrAE engines");
    println!("  serve-bench [--smoke] load-test the sharded, batched, multi-store serving engine;");
    println!("                        emits BENCH_serve.json (NSCOG_SERVE_JSON overrides path).");
    println!("                        knobs: --requests N --clients N --workers N --shards N");
    println!("                               --batch N --delay-us N --queue N --rate QPS --json PATH");
    println!("                        scan fan-out per worker: NSCOG_THREADS / --scan-threads N");
    println!("                        pruned scans: --sketch-bits N (prefilter sidecar width;");
    println!("                               0 = incremental bounds only; default 512 for dim>=2048)");
    println!("                        sketch cascade: --sketch-cascade BITS (coarse first-level");
    println!("                               prefix, e.g. 128; orders + bulk-rejects the tail before");
    println!("                               the full sketch refines survivors; exactness unchanged,");
    println!("                               per-level rejects in the JSON prune blocks)");
    println!("                        row storage: --store-backing ram|ca90 (ca90 keeps per-item");
    println!("                               512-bit seeds only and rematerializes rows inside the");
    println!("                               scan loop — ~dim/512 less resident row memory, same");
    println!("                               bit-exact answers; requires dim % 512 == 0; resident");
    println!("                               bytes per store in the JSON \"memory\" blocks)");
    println!("                        response cache (per store): --cache N (entry budget,");
    println!("                               0 disables; default 4096) --cache-shards N (default 8)");
    println!("                        workload reuse: --repeat F (fraction of repeated queries)");
    println!("                        query noise: --noise F (fraction of bits flipped on recall");
    println!("                               queries; low noise = high-score regime where the");
    println!("                               coarse cascade level bulk-rejects)");
    println!("                        multi-store: --stores N (N tenants behind one queue;");
    println!("                               skewed popularity, dims alternate base/2x base);");
    println!("                               per-store overrides (comma lists, cycled):");
    println!("                               --store-dims D,.. --store-items N,.. --store-sketch B,..");
    println!("                               --store-weights W,.. --store-repeat F,..");
    println!("                        overload control: --store-quotas Q,.. (per-store admission");
    println!("                               quota / DRR lane bound; 0 = global capacity only;");
    println!("                               weights double as DRR pop shares)");
    println!("                        fault injection: --faults reject=P,panic=P,delay-prob=P,");
    println!("                               delay-us=N,seed=S (deterministic; probs in [0,1])");
    println!("                        chaos: --chaos flood|deadline|panic|churn|slowloris|halfopen|");
    println!("                               disconnect|garbage (runs after the clean passes on a");
    println!("                               fresh engine; fairness + liveness gated, verdict in");
    println!("                               the JSON's \"chaos\" block; the four network scenarios");
    println!("                               attack a real TCP listener while victim clients must");
    println!("                               stay bit-exact, with a \"net\" ledger proving");
    println!("                               completed + refused + expired == offered)");
    println!("                        wire: --wire adds a TCP socket pass after the in-process");
    println!("                               passes — the whole schedule through the framed");
    println!("                               protocol via real connections, bit-exact gated,");
    println!("                               socket counters folded into the JSON's \"wire\" block");
    println!("                        churn: live item insert/delete and store create/drop racing");
    println!("                               traffic via epoch-based snapshot swap; every answer");
    println!("                               verified against its seal-window epoch oracle, dropped");
    println!("                               stores must answer UnknownStore, epochs must be");
    println!("                               strictly monotonic, post-churn probe bit-exact.");
    println!("                               knobs: --churn-rate OPS_PER_S (default 150)");
    println!("                                      --churn-ops N (default 60)");
    println!("                        tracing: --trace (or NSCOG_TRACE=1) record per-request stage");
    println!("                               marks (admit/pop/seal/kernel/fill) into a drop-oldest");
    println!("                               event ring and emit BENCH_serve_trace.json — stage");
    println!("                               latency breakdowns plus a measured roofline verdict");
    println!("                               per request class (NSCOG_SERVE_TRACE_JSON overrides");
    println!("                               the path)");
    println!("                        --trace-capacity N (ring size, default 4096) --trace-json PATH");
    println!("                        host roofline calibration: NSCOG_HOST_PEAK_FLOPS and");
    println!("                               NSCOG_HOST_DRAM_BW override the Xeon 4114 defaults");
    println!("  serve --listen ADDR   expose the serving engine on a TCP socket (framed, length-");
    println!("                        prefixed wire protocol v1; see PERF.md 'Network front-end').");
    println!("                        knobs: --stores N (tenants, default 1)");
    println!("                               --duration-s S (0 = serve until killed, default)");
    println!("                        per-connection read/write deadlines, slow-loris and");
    println!("                        half-open reaping, overload answered with error frames");
    println!("  runtime-info          check PJRT artifacts (artifacts/manifest.json)");
}

/// Report (but do not abort on) invalid workload traces: one bad
/// workload must not take down `figures`/`characterize` for the rest.
fn report_invalid_workloads() {
    if let Err(errors) = nscog::workloads::validate_all() {
        for e in &errors {
            eprintln!("WARNING: workload validation: {e}");
        }
        eprintln!(
            "WARNING: {} workload(s) failed validation; continuing with the rest",
            errors.len()
        );
    }
}

fn figures() {
    use nscog::figures as f;
    report_invalid_workloads();
    // Figures are generated lazily and each one is isolated: a workload
    // that panics while building one table (e.g. an invalid trace) fails
    // that figure alone instead of aborting the whole run.
    let figs: Vec<(&str, fn() -> nscog::util::bench::Table)> = vec![
        ("Fig. 2a — neural vs symbolic runtime", f::fig2a),
        ("Fig. 2b — edge platform latency (NVSA, NLM)", f::fig2b),
        ("Fig. 2c — NVSA task-size scaling", f::fig2c),
        ("Fig. 3a — operator category breakdown", f::fig3a),
        ("Fig. 3b — memory usage", f::fig3b),
        ("Fig. 3c — roofline placement", f::fig3c),
        ("Fig. 4 — operator graph / critical path", f::fig4),
        ("Tab. IV — kernel hardware counters", f::tab4),
        ("Fig. 5 — NVSA symbolic sparsity", f::fig5),
        ("Fig. 9 — SOPC vs MOPC", f::fig9),
        ("Fig. 11a — accelerator scaling", f::fig11a),
        ("Fig. 11b — accelerator vs GPU", f::fig11b),
    ];
    let mut failed = 0;
    for (title, build) in figs {
        println!("== {title} ==");
        match std::panic::catch_unwind(build) {
            Ok(table) => table.print(),
            Err(_) => {
                failed += 1;
                eprintln!("FAILED to generate {title} (see warnings above)");
            }
        }
        println!();
    }
    if failed > 0 {
        eprintln!("{failed} figure(s) failed; the rest were generated");
        std::process::exit(1);
    }
}

fn characterize(name: Option<&str>) {
    let gpu = Platform::rtx2080ti();
    for w in all_workloads() {
        if let Some(n) = name {
            if !w.name().eq_ignore_ascii_case(n) {
                continue;
            }
        }
        let trace = w.trace();
        if let Err(e) = nscog::workloads::validate_trace(w.name(), &trace) {
            eprintln!("WARNING: skipping {}: {e}", w.name());
            continue;
        }
        let report = WorkloadReport::build(&trace, w.memory(), vec![], &gpu);
        println!("{}", report.summary_line());
        for pt in &report.roofline {
            println!(
                "    {} phase: intensity {:.3} FLOP/B → {}",
                pt.phase.label(),
                pt.intensity,
                if pt.memory_bound {
                    "memory-bound"
                } else {
                    "compute-bound"
                }
            );
        }
    }
}

fn accel(cfg_name: &str, workload: &str) {
    let cfg = match cfg_name {
        "acc2" => AccelConfig::acc2(),
        "acc8" => AccelConfig::acc8(),
        _ => AccelConfig::acc4(),
    };
    let kind = match workload {
        "mult" => SuiteKind::Mult,
        "tree" => SuiteKind::Tree,
        "react" => SuiteKind::React,
        _ => SuiteKind::Fact,
    };
    println!("{} on {} ({} tiles)", kind.label(), cfg.name, cfg.n_tiles);
    for control in [ControlMethod::Sopc, ControlMethod::Mopc] {
        let mut s = CompiledSuite::build(kind, cfg.clone(), 17);
        let r = s.run(control);
        println!(
            "  {control}: {} words, {} cycles, {}, {:.3} mW avg",
            r.words,
            r.cycles,
            fmt_time(r.time_s),
            r.avg_power_w() * 1e3
        );
    }
}

fn solve(grid: usize) {
    use nscog::workloads::nvsa::{Nvsa, NvsaEngine};
    use nscog::workloads::prae::Prae;
    let mut rng = nscog::util::Rng::new(2024);
    let nvsa = NvsaEngine::new(
        Nvsa {
            grid,
            ..Default::default()
        },
        1,
    );
    let prae = Prae {
        grid,
        ..Default::default()
    };
    let n = 20;
    let mut nvsa_ok = 0;
    let mut prae_ok = 0;
    for i in 0..n {
        let inst = raven::generate(&mut rng, grid, 8);
        let pmfs = raven::panel_pmfs(&inst, 0.95);
        let sn = nvsa.solve(&inst, &pmfs);
        let sp = prae.solve(&inst, &pmfs);
        nvsa_ok += sn.correct as usize;
        prae_ok += sp.correct as usize;
        if i < 3 {
            println!(
                "instance {i}: rules {:?} → NVSA {} PrAE {}",
                inst.rules.iter().map(|r| r.label()).collect::<Vec<_>>(),
                if sn.correct { "ok" } else { "MISS" },
                if sp.correct { "ok" } else { "MISS" },
            );
        }
    }
    println!(
        "{grid}x{grid} RPM over {n} instances: NVSA {:.0}%  PrAE {:.0}%",
        nvsa_ok as f64 / n as f64 * 100.0,
        prae_ok as f64 / n as f64 * 100.0
    );
}

fn serve_bench(flags: &[String]) {
    use nscog::serve::loadgen::{run_bench, BenchOpts, ChaosScenario};
    use nscog::serve::FaultConfig;

    let has = |name: &str| flags.iter().any(|a| a == name);
    let val = |name: &str| {
        flags
            .iter()
            .position(|a| a == name)
            .and_then(|i| flags.get(i + 1))
    };
    let num = |name: &str| val(name).and_then(|v| v.parse::<usize>().ok());

    let mut opts = if has("--smoke") {
        BenchOpts::smoke()
    } else {
        BenchOpts::standard()
    };
    if let Some(n) = num("--requests") {
        opts.fixture.requests = n.max(1);
    }
    if let Some(n) = num("--clients") {
        opts.clients = n.max(1);
    }
    if let Some(n) = num("--workers") {
        opts.engine.workers = n.max(1);
    }
    if let Some(n) = num("--shards") {
        opts.engine.shards = n.max(1);
    }
    if let Some(n) = num("--scan-threads") {
        opts.engine.scan_threads = n.max(1);
    } else {
        let env = nscog::util::parallel::configured_threads();
        if env > 1 {
            opts.engine.scan_threads = env;
        }
    }
    if let Some(n) = num("--batch") {
        opts.engine.max_batch = n.max(1);
    }
    if let Some(n) = num("--delay-us") {
        opts.engine.max_delay = std::time::Duration::from_micros(n as u64);
    }
    if let Some(n) = num("--queue") {
        opts.engine.queue_capacity = n.max(1);
    }
    if let Some(rate) = val("--rate").and_then(|v| v.parse::<f64>().ok()) {
        if rate > 0.0 {
            opts.open_loop_qps = Some(rate);
        }
    }
    opts.wire = has("--wire");
    if let Some(n) = num("--sketch-bits") {
        opts.engine.sketch_bits = Some(n);
    }
    // two-level sketch cascade (coarse prefix width; applies to every
    // store — per-store sketch widths still come from --store-sketch)
    let sketch_cascade = num("--sketch-cascade");
    // row-storage mode for every store's master codebook
    let backing = val("--store-backing").map(|v| {
        match nscog::serve::loadgen::StoreBacking::parse(v) {
            Some(b) => b,
            None => {
                eprintln!("unknown --store-backing '{v}' (expected ram|ca90)");
                std::process::exit(2);
            }
        }
    });
    if let Some(n) = num("--cache") {
        opts.engine.cache_capacity = n;
    }
    if let Some(n) = num("--cache-shards") {
        opts.engine.cache_shards = n.max(1);
    }
    if let Some(frac) = val("--repeat").and_then(|v| v.parse::<f64>().ok()) {
        for p in &mut opts.fixture.stores {
            p.repeat_frac = frac.clamp(0.0, 1.0);
        }
    }
    // recall-query noise (fraction of bits flipped on the member item);
    // low noise is the high-score regime where the coarse cascade level
    // can actually bulk-reject the tail
    if let Some(frac) = val("--noise").and_then(|v| v.parse::<f64>().ok()) {
        opts.fixture.noise_frac = frac.clamp(0.0, 1.0);
    }
    // multi-store expansion first, per-store overrides layered on top
    // (comma lists cycle over the stores, so one value applies to all)
    if let Some(n) = num("--stores") {
        opts.with_stores(n.max(1));
    }
    let list = |name: &str| -> Vec<String> {
        val(name)
            .map(|v| v.split(',').map(str::to_string).collect())
            .unwrap_or_default()
    };
    let dims = list("--store-dims");
    let items = list("--store-items");
    let sketch = list("--store-sketch");
    let weights = list("--store-weights");
    let repeats = list("--store-repeat");
    let quotas = list("--store-quotas");
    for (i, p) in opts.fixture.stores.iter_mut().enumerate() {
        let pick = |xs: &[String]| -> Option<String> {
            if xs.is_empty() {
                None
            } else {
                Some(xs[i % xs.len()].clone())
            }
        };
        if let Some(d) = pick(&dims).and_then(|v| v.parse::<usize>().ok()) {
            p.dim = d.max(64);
        }
        if let Some(n) = pick(&items).and_then(|v| v.parse::<usize>().ok()) {
            p.items = n.max(1);
        }
        if let Some(b) = pick(&sketch).and_then(|v| v.parse::<usize>().ok()) {
            p.sketch_bits = Some(b);
        }
        if let Some(w) = pick(&weights).and_then(|v| v.parse::<u32>().ok()) {
            p.weight = w.max(1);
        }
        if let Some(fr) = pick(&repeats).and_then(|v| v.parse::<f64>().ok()) {
            p.repeat_frac = fr.clamp(0.0, 1.0);
        }
        if let Some(q) = pick(&quotas).and_then(|v| v.parse::<usize>().ok()) {
            // 0 = unbounded lane (global capacity only)
            p.quota = if q == 0 { None } else { Some(q) };
        }
        if let Some(b) = backing {
            p.backing = b;
        }
        if let Some(bits) = sketch_cascade {
            // 0 = explicit single-level sketch
            p.sketch_cascade = if bits == 0 { None } else { Some(bits) };
        }
    }
    // ca90 rematerialization derives rows from 512-bit seeds: reject
    // unaligned dims here instead of panicking mid-fixture
    for p in &opts.fixture.stores {
        if p.backing == nscog::serve::loadgen::StoreBacking::Ca90
            && (p.dim == 0 || p.dim % 512 != 0)
        {
            eprintln!(
                "--store-backing ca90 requires every store dim to be a positive multiple of 512 \
                 (store '{}' has dim {})",
                p.name, p.dim
            );
            std::process::exit(2);
        }
    }
    if let Some(p) = val("--json") {
        opts.json_path = Some(p.clone());
    }
    // stage tracing: flags win over the NSCOG_TRACE environment toggle;
    // either --trace-capacity or --trace-json alone also turns it on
    let env_trace = std::env::var("NSCOG_TRACE")
        .map(|v| {
            let v = v.trim();
            !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
        })
        .unwrap_or(false);
    opts.trace = has("--trace") || env_trace;
    if let Some(n) = num("--trace-capacity") {
        opts.trace = true;
        opts.trace_capacity = n.max(1);
    }
    if let Some(p) = val("--trace-json") {
        opts.trace = true;
        opts.trace_json_path = Some(p.clone());
    }
    if let Some(spec) = val("--chaos") {
        match ChaosScenario::parse(spec) {
            Some(sc) => opts.chaos = Some(sc),
            None => {
                eprintln!(
                    "unknown --chaos scenario '{spec}' \
                     (expected flood|deadline|panic|churn|slowloris|halfopen|disconnect|garbage)"
                );
                std::process::exit(2);
            }
        }
    }
    if let Some(r) = val("--churn-rate").and_then(|v| v.parse::<f64>().ok()) {
        if r > 0.0 {
            opts.churn_rate = r;
        }
    }
    if let Some(n) = num("--churn-ops") {
        opts.churn_ops = n.max(1);
    }
    if let Some(spec) = val("--faults") {
        // --faults reject=0.05,panic=0.25,delay-us=200,delay-prob=0.5,seed=7
        let mut fc = FaultConfig::default();
        for kv in spec.split(',').filter(|s| !s.is_empty()) {
            let (key, v) = match kv.split_once('=') {
                Some(pair) => pair,
                None => {
                    eprintln!("bad --faults entry '{kv}' (expected key=value)");
                    std::process::exit(2);
                }
            };
            let ok = match key {
                "reject" => v.parse().map(|p| fc.admit_reject_prob = p).is_ok(),
                "panic" => v.parse().map(|p| fc.panic_prob = p).is_ok(),
                "delay-prob" => v.parse().map(|p| fc.kernel_delay_prob = p).is_ok(),
                "delay-us" => v
                    .parse::<u64>()
                    .map(|us| fc.kernel_delay = std::time::Duration::from_micros(us))
                    .is_ok(),
                "seed" => v.parse().map(|s| fc.seed = s).is_ok(),
                _ => false,
            };
            if !ok {
                eprintln!(
                    "bad --faults entry '{kv}' (keys: reject, panic, delay-prob, delay-us, seed)"
                );
                std::process::exit(2);
            }
        }
        opts.engine.faults = Some(fc);
    }

    let f = &opts.fixture;
    let e = &opts.engine;
    println!(
        "serve-bench: {} requests (mix {}:{}:{}) over {} store(s)",
        f.requests,
        f.mix.recall,
        f.mix.topk,
        f.mix.factorize,
        f.stores.len()
    );
    for p in &f.stores {
        println!(
            "  store '{}': {}x{}b cleanup, topk k={}, weight {}, repeat {:.2}, sketch {}",
            p.name,
            p.items,
            p.dim,
            p.topk_k,
            p.weight,
            p.repeat_frac,
            match p.sketch_bits {
                Some(b) => b.to_string(),
                None => "auto".into(),
            }
        );
    }
    println!(
        "engine: {} workers x batch<={} (delay {}us), {} shards, {} scan threads, queue {}",
        e.workers,
        e.max_batch,
        e.max_delay.as_micros(),
        e.shards,
        e.scan_threads,
        e.queue_capacity
    );
    println!(
        "simd: dispatch tier '{}' (NSCOG_SIMD overrides)",
        nscog::vsa::kernels::active_tier().name()
    );
    println!(
        "pruning: sketch {} bits (engine default); cache per store: {}",
        match e.sketch_bits {
            Some(b) => b.to_string(),
            None => "auto".into(),
        },
        if e.cache_capacity > 0 {
            format!("{} entries x {} shards", e.cache_capacity, e.cache_shards)
        } else {
            "disabled".into()
        }
    );
    let report = run_bench(opts);
    report.table().print();
    println!(
        "batching: {} batches, mean occupancy {:.2}, max {}",
        report.stats.batches, report.stats.mean_batch, report.stats.max_batch
    );
    for store in &report.stats.stores {
        let p = &store.prune;
        let cache_line = match &store.cache {
            Some(c) => format!(
                "cache {:.1}% hit ({} hits/{} misses, {} resident)",
                c.hit_rate() * 100.0,
                c.hits,
                c.misses,
                c.entries
            ),
            None => "cache disabled".into(),
        };
        let mem_line = match &store.memory {
            Some(m) => format!(
                "{} resident: rows {} + sketch {} + master {}",
                m.backing,
                fmt_bytes(m.row_bytes),
                fmt_bytes(m.sketch_bytes),
                fmt_bytes(m.master_bytes)
            ),
            None => "memory: n/a (dropped)".into(),
        };
        println!(
            "  store '{}': {} completed, {:.1}% words streamed (coarse reject {:.1}%, sketch reject {:.1}%), {}, {}",
            store.name,
            store.completed,
            p.words_frac() * 100.0,
            p.coarse_reject_rate() * 100.0,
            p.sketch_reject_rate() * 100.0,
            cache_line,
            mem_line
        );
        for (s, sh) in store.shards.iter().enumerate() {
            println!(
                "    shard {s}: {} scans, busy {}",
                sh.scans,
                fmt_time(sh.busy_s)
            );
        }
    }
    let p = &report.stats.prune;
    println!(
        "pruned scans (all stores): {:.1}% of item words streamed ({} items; coarse reject {:.1}%, sketch reject {:.1}%, {} early-terminated)",
        p.words_frac() * 100.0,
        p.items,
        p.coarse_reject_rate() * 100.0,
        p.sketch_reject_rate() * 100.0,
        p.early_terminated
    );
    match &report.stats.cache {
        Some(c) => println!(
            "cache (all stores): hit rate {:.1}% ({} hits / {} misses), {} entries resident, {} evictions",
            c.hit_rate() * 100.0,
            c.hits,
            c.misses,
            c.entries,
            c.evictions
        ),
        None => println!("cache: disabled"),
    }
    println!(
        "QPS speedup vs unbatched single-thread baseline: {:.2}x",
        report.speedup_qps()
    );
    if let Some(w) = &report.wire {
        let c = &w.counters;
        println!(
            "wire (tcp): {} ok / {} rejected / {} expired, {} mismatches, {} io errors",
            w.pass.ok,
            w.pass.rejected + w.pass.rejected_tenant,
            w.pass.expired,
            w.pass.mismatches,
            w.net_errors
        );
        println!(
            "  sockets: {} conns, {} frames in / {} out, {} B in / {} B out, \
             {} protocol errors, {} reaped",
            c.accepted,
            c.frames_in,
            c.frames_out,
            c.bytes_in,
            c.bytes_out,
            c.protocol_errors,
            c.slowloris_reaped + c.halfopen_reaped
        );
    }
    if let Some(log) = &report.trace {
        use nscog::serve::RequestKind;
        println!(
            "trace: {} events buffered (ring capacity {}), {} dropped oldest",
            log.events.len(),
            log.capacity,
            log.dropped
        );
        let mean = |l: &Option<nscog::serve::LatencySummary>| {
            l.as_ref().map_or(0.0, |s| s.mean_s)
        };
        for st in &report.stats.stages {
            if st.n == 0 {
                continue;
            }
            // wire spans only exist for socket-borne requests (--wire)
            let net = match (&st.net_in, &st.net_out) {
                (None, None) => String::new(),
                (i, o) => format!(
                    "  [net in {} / out {}]",
                    fmt_time(mean(i)),
                    fmt_time(mean(o))
                ),
            };
            println!(
                "  stages[{}]: n={}  queue {} + batch {} + kernel {} + fill {}  (e2e {}){}",
                st.kind.label(),
                st.n,
                fmt_time(mean(&st.queue)),
                fmt_time(mean(&st.batch)),
                fmt_time(mean(&st.kernel)),
                fmt_time(mean(&st.fill)),
                fmt_time(mean(&st.total)),
                net
            );
        }
        let host = Platform::host();
        let ridge = nscog::profiler::roofline::ridge_intensity(&host);
        for k in RequestKind::ALL {
            let w = &report.stats.kernel_work[k.index()];
            if w.calls == 0 {
                continue;
            }
            println!(
                "  roofline[{}]: {:.3} FLOP/B at {:.2} GFLOP/s → {} on {} (ridge {:.2})",
                k.label(),
                w.intensity(),
                w.attained_flops() / 1e9,
                if w.intensity() < ridge {
                    "memory-bound"
                } else {
                    "compute-bound"
                },
                host.name,
                ridge
            );
        }
        match report.write_trace_json() {
            Ok(Some(path)) => println!("wrote {path}"),
            Ok(None) => {}
            Err(e) => eprintln!("could not write serve trace JSON: {e}"),
        }
    }
    // write the JSON even on failure so CI has the evidence, then gate
    match report.write_json() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write serve bench JSON: {e}"),
    }
    let mismatches = report.closed.mismatches
        + report.open.as_ref().map_or(0, |(_, p)| p.mismatches)
        + report.wire.as_ref().map_or(0, |w| w.pass.mismatches);
    if mismatches > 0 {
        eprintln!(
            "ERROR: {mismatches} batched responses diverged from the sequential oracle"
        );
        std::process::exit(1);
    }
    if let Some(w) = &report.wire {
        if w.net_errors > 0 {
            eprintln!(
                "ERROR: {} transport errors during the wire pass",
                w.net_errors
            );
            std::process::exit(1);
        }
    }
    if let Some(chaos) = &report.chaos {
        println!(
            "chaos '{}': fairness {}, liveness {}",
            chaos.scenario.name(),
            if chaos.fairness_pass { "PASS" } else { "FAIL" },
            if chaos.liveness_pass { "PASS" } else { "FAIL" }
        );
        for s in &chaos.stores {
            println!(
                "  store '{}'{}: {} offered, {} completed ({} degraded), {} tenant-rejected, {} rejected, {} expired, {} internal, {} mismatches",
                s.name,
                if s.flooder { " [misbehaving]" } else { "" },
                s.offered,
                s.completed,
                s.degraded,
                s.rejected_tenant,
                s.rejected,
                s.expired,
                s.internal,
                s.mismatches
            );
        }
        if let Some(c) = &chaos.churn {
            println!(
                "  churn: {} ops ({} insert / {} delete / {} create / {} drop, {} refused), \
                 wrong-epoch {}, unknown ok/bad {}/{}, panics {}, epochs {}, probe {}",
                c.ops,
                c.inserts,
                c.deletes,
                c.creates,
                c.drops,
                c.op_failures,
                c.wrong_epoch,
                c.unknown_ok,
                c.unknown_bad,
                c.panics,
                if c.monotonic { "monotonic" } else { "NON-MONOTONIC" },
                if c.probe_pass {
                    format!("{} stores bit-exact", c.probed)
                } else {
                    "FAILED".into()
                }
            );
            for (name, epoch) in &c.final_epochs {
                println!("    store '{name}': final epoch {epoch}");
            }
        }
        if let Some(n) = &chaos.net {
            println!(
                "  net: {} offered = {} completed + {} refused + {} expired ({}), \
                 {} mismatches, {} io errors",
                n.offered,
                n.completed,
                n.refused,
                n.expired,
                if n.accounting_exact { "exact" } else { "INEXACT" },
                n.mismatches,
                n.net_errors
            );
            println!(
                "       reaped {} ({}), {} protocol errors, {} disconnects, victims {}, probe {}",
                n.reaped,
                if n.reap_within_deadline {
                    "within deadline"
                } else {
                    "LATE/NONE"
                },
                n.protocol_errors,
                n.disconnects,
                if n.victim_clean { "clean" } else { "DAMAGED" },
                if n.probe_pass { "bit-exact" } else { "FAILED" }
            );
        }
        if !chaos.fairness_pass || !chaos.liveness_pass {
            eprintln!(
                "ERROR: chaos scenario '{}' violated its fairness/liveness invariants",
                chaos.scenario.name()
            );
            std::process::exit(1);
        }
    }
}

/// Expose the serving engine on a real TCP socket: a deterministic
/// multi-store fixture behind the framed wire protocol, with the
/// connection-robustness defaults (read/write deadlines, slow-loris and
/// half-open reaping, overload answered as error frames).
fn serve(flags: &[String]) {
    use nscog::serve::loadgen::{BenchOpts, Fixture};
    use nscog::serve::{net, NetConfig, NetServer, ServeEngine};
    use std::sync::Arc;

    let val = |name: &str| {
        flags
            .iter()
            .position(|a| a == name)
            .and_then(|i| flags.get(i + 1))
    };
    let num = |name: &str| val(name).and_then(|v| v.parse::<usize>().ok());
    let addr = val("--listen").cloned().unwrap_or_else(|| {
        eprintln!("serve: --listen ADDR is required (e.g. --listen 127.0.0.1:7070)");
        std::process::exit(2);
    });
    let stores = num("--stores").unwrap_or(1).max(1);
    let duration_s = num("--duration-s").unwrap_or(0) as u64;

    // the smoke fixture gives small, deterministic stores to serve
    let mut opts = BenchOpts::smoke();
    opts.with_stores(stores);
    let fixture = Fixture::build(opts.fixture.clone());
    let engine = Arc::new(
        ServeEngine::start_registry(fixture.registry(&opts.engine), opts.engine.clone())
            .expect("spawn serve workers"),
    );
    let server = match NetServer::start(Arc::clone(&engine), &addr, NetConfig::default()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: could not bind {addr}: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "serving {} store(s) on {} (framed wire protocol v{})",
        stores,
        server.addr(),
        net::frame::VERSION
    );
    for p in &opts.fixture.stores {
        println!("  store '{}': {}x{}b cleanup", p.name, p.items, p.dim);
    }
    if duration_s == 0 {
        println!("serving until killed (--duration-s S bounds the run)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration_s));
    let c = server.counters();
    println!(
        "served {} response frames over {} connection(s): {} frames in, \
         {} protocol errors, {} refused, {} reaped, {} disconnects",
        c.frames_out,
        c.accepted,
        c.frames_in,
        c.protocol_errors,
        c.refused,
        c.slowloris_reaped + c.halfopen_reaped,
        c.disconnects
    );
    server.shutdown();
    if let Ok(e) = Arc::try_unwrap(engine) {
        e.shutdown();
    }
}

fn runtime_info() {
    match nscog::runtime::Runtime::new() {
        Err(e) => {
            eprintln!("runtime unavailable: {e}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
        Ok(mut rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("dims: {:?}", rt.manifest.dims);
            let names: Vec<String> = rt.manifest.artifacts.keys().cloned().collect();
            for name in names {
                match rt.load(&name) {
                    Ok(exe) => println!(
                        "  {name}: {} in / {} out — compiled OK",
                        exe.spec.inputs.len(),
                        exe.spec.outputs.len()
                    ),
                    Err(e) => println!("  {name}: FAILED: {e}"),
                }
            }
        }
    }
}
