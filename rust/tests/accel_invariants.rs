//! Property-based invariants of the accelerator simulator: the compiled
//! kernels must agree with the functional VSA substrate for random data,
//! layouts, and configurations, and SOPC/MOPC must be architecturally
//! indistinguishable.

use nscog::accel::compiler::{KernelCompiler, Operand, VecRef};
use nscog::accel::isa::ControlMethod;
use nscog::accel::pipeline::Accelerator;
use nscog::accel::AccelConfig;
use nscog::util::prop::forall_res;
use nscog::util::Rng;
use nscog::vsa::{BinaryCodebook, BinaryHV};

fn random_cfg(rng: &mut Rng) -> AccelConfig {
    match rng.below(3) {
        0 => AccelConfig::acc2(),
        1 => AccelConfig::acc4(),
        _ => AccelConfig::acc8(),
    }
}

#[test]
fn prop_search_always_matches_functional_nearest() {
    forall_res(
        0xA11CE,
        25,
        |rng| {
            let cfg = random_cfg(rng);
            let n_items = 3 + rng.below(40);
            let dim = 512 * (1 + rng.below(8));
            (cfg, n_items, dim, rng.next_u64())
        },
        |(cfg, n_items, dim, seed)| {
            let mut rng = Rng::new(*seed);
            let cb = BinaryCodebook::random(&mut rng, *n_items, *dim);
            let q = BinaryHV::random(&mut rng, *dim);
            let mut acc = Accelerator::new(cfg.clone());
            let layout = acc.load_items(cb.items(), 2);
            let kc = KernelCompiler::new(cfg.clone(), layout);
            acc.stage_scratch(&kc.layout, 0, &q);
            acc.reset_search();
            acc.run(&kc.search(0, *n_items), ControlMethod::Mopc);
            let (gid, score) = acc.global_best(&kc.layout);
            let (eid, escore) = cb.nearest(&q);
            if score != escore {
                return Err(format!("score {score} != functional {escore}"));
            }
            if gid != eid {
                return Err(format!("winner {gid} != functional {eid}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bind_chain_matches_functional() {
    forall_res(
        0xB14D,
        20,
        |rng| {
            let cfg = random_cfg(rng);
            let n_ops = 2 + rng.below(3);
            let dim = 512 * (1 + rng.below(4));
            (cfg, n_ops, dim, rng.next_u64())
        },
        |(cfg, n_ops, dim, seed)| {
            let mut rng = Rng::new(*seed);
            let cb = BinaryCodebook::random(&mut rng, 8, *dim);
            let ids: Vec<usize> = (0..*n_ops).map(|_| rng.below(8)).collect();
            let mut acc = Accelerator::new(cfg.clone());
            let layout = acc.load_items(cb.items(), 2);
            let kc = KernelCompiler::new(cfg.clone(), layout);
            let ops: Vec<Operand> = ids
                .iter()
                .map(|&i| Operand::plain(VecRef::Item(i)))
                .collect();
            acc.run(&kc.bind(&ops, 0), ControlMethod::Sopc);
            let mut expect = cb.item(ids[0]).clone();
            for &i in &ids[1..] {
                expect = expect.bind(cb.item(i));
            }
            for t in 0..acc.cfg.n_tiles {
                if acc.read_scratch(&kc.layout, t, 0) != expect {
                    return Err(format!("tile {t} result mismatch"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sopc_mopc_identical_state_and_energy() {
    forall_res(
        0x50BC1,
        15,
        |rng| (random_cfg(rng), rng.next_u64()),
        |(cfg, seed)| {
            let mut rng = Rng::new(*seed);
            let cb = BinaryCodebook::random(&mut rng, 12, 2048);
            let q = BinaryHV::random(&mut rng, 2048);
            let build = || {
                let mut acc = Accelerator::new(cfg.clone());
                let layout = acc.load_items(cb.items(), 3);
                let kc = KernelCompiler::new(cfg.clone(), layout);
                (acc, kc)
            };
            let (mut a, kc) = build();
            let (mut b, _) = build();
            for acc in [&mut a, &mut b] {
                acc.stage_scratch(&kc.layout, 0, &q);
                acc.reset_search();
            }
            let prog = kc.project(0, &[0, 1, 2, 3, 4], 1);
            let ra = a.run(&prog, ControlMethod::Sopc);
            let rb = b.run(&prog, ControlMethod::Mopc);
            if a.read_scratch(&kc.layout, 0, 1) != b.read_scratch(&kc.layout, 0, 1) {
                return Err("projection state differs".into());
            }
            if (ra.dynamic_j - rb.dynamic_j).abs() > 1e-18 {
                return Err("dynamic energy differs between controls".into());
            }
            if rb.cycles >= ra.cycles {
                return Err(format!(
                    "MOPC ({}) not faster than SOPC ({})",
                    rb.cycles, ra.cycles
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ca90_compressed_codebook_roundtrips() {
    forall_res(
        0xCA90,
        20,
        |rng| (8 + rng.below(32), 512 * (1 + rng.below(16)), rng.next_u64()),
        |(n, dim, seed)| {
            let mut rng = Rng::new(*seed);
            let cb = BinaryCodebook::random(&mut rng, *n, *dim);
            // compress to seeds, re-expand, and check expansion determinism
            let expanded = BinaryCodebook::from_seeds(&cb.seeds(), *dim);
            let again = BinaryCodebook::from_seeds(&cb.seeds(), *dim);
            for i in 0..*n {
                if expanded.item(i) != again.item(i) {
                    return Err(format!("CA-90 expansion non-deterministic at {i}"));
                }
                // expanded items stay quasi-orthogonal
                for j in 0..i {
                    let cos = expanded.item(i).cosine(expanded.item(j));
                    if cos.abs() > 0.2 {
                        return Err(format!("items {i},{j} correlated: {cos}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_energy_monotone_in_tile_count_for_broadcast() {
    // Broadcasting the same search to more tiles must not reduce total
    // dynamic energy (per-tile stages replicate).
    forall_res(
        0xE4E61,
        10,
        |rng| rng.next_u64(),
        |seed| {
            let mut rng = Rng::new(*seed);
            let cb = BinaryCodebook::random(&mut rng, 16, 1024);
            let q = BinaryHV::random(&mut rng, 1024);
            let mut energies = Vec::new();
            for cfg in [AccelConfig::acc2(), AccelConfig::acc8()] {
                let mut acc = Accelerator::new(cfg.clone());
                let layout = acc.load_items(cb.items(), 2);
                let kc = KernelCompiler::new(cfg, layout);
                acc.stage_scratch(&kc.layout, 0, &q);
                acc.reset_search();
                let r = acc.run(&kc.search(0, 16), ControlMethod::Mopc);
                energies.push((r.time_s, r.dynamic_j));
            }
            // Acc8 must be faster; dynamic energy similar scale (same work)
            if energies[1].0 >= energies[0].0 {
                return Err(format!(
                    "Acc8 search not faster: {:?}",
                    energies
                ));
            }
            Ok(())
        },
    );
}
