//! Property tests for the serve-path tracer (PR 7): per-ticket stage
//! timestamps must decompose monotonically (no stage span negative, and
//! the four spans can never attribute more time than the request's
//! end-to-end latency), every completed response must surface a full
//! lifecycle event in the ring, ring overflow must drop oldest with an
//! exact counter, per-class stage means must reconcile with the same
//! requests' end-to-end means in the stats snapshot, and the queue
//! gauges must be layered into engine snapshots.

use nscog::serve::loadgen::{
    run_closed_loop, Fixture, FixtureConfig, LoadMix, StoreBacking, StoreProfile,
};
use nscog::serve::{EngineConfig, RequestKind, ServeEngine, TraceEvent};
use std::time::Duration;

fn base_profile() -> StoreProfile {
    StoreProfile {
        name: "default".into(),
        items: 24,
        dim: 512,
        topk_k: 3,
        fact_factors: 3,
        fact_items: 6,
        fact_dim: 256,
        fact_iters: 20,
        weight: 1,
        repeat_frac: 0.0,
        sketch_bits: None,
        quota: None,
        backing: StoreBacking::Ram,
        sketch_cascade: None,
    }
}

fn fixture_cfg(requests: usize, seed: u64) -> FixtureConfig {
    FixtureConfig {
        stores: vec![base_profile()],
        noise_frac: 0.2,
        requests,
        mix: LoadMix {
            recall: 4,
            topk: 2,
            factorize: 1,
        },
        seed,
    }
}

fn traced_engine(fixture: &Fixture, capacity: usize) -> ServeEngine {
    let cfg = EngineConfig {
        workers: 2,
        shards: 3,
        max_batch: 8,
        max_delay: Duration::from_micros(500),
        trace_capacity: Some(capacity),
        ..EngineConfig::default()
    };
    ServeEngine::start_registry(fixture.registry(&cfg), cfg).expect("spawn serve workers")
}

/// Every stage span is non-negative and their sum never exceeds the
/// event's end-to-end latency — the timestamp-monotonicity invariant as
/// seen through the saturating stage decomposition.
fn assert_decomposition(ev: &TraceEvent) {
    let s = &ev.stages;
    for (name, span) in [
        ("queue", s.queue_s),
        ("batch", s.batch_s),
        ("kernel", s.kernel_s),
        ("fill", s.fill_s),
    ] {
        assert!(span >= 0.0, "{name} span negative: {span}");
        assert!(span.is_finite(), "{name} span not finite: {span}");
    }
    assert!(
        s.sum() <= ev.total_s + 1e-9,
        "stage sum {} exceeds e2e latency {}",
        s.sum(),
        ev.total_s
    );
}

#[test]
fn every_completed_response_carries_a_full_lifecycle_event() {
    let fixture = Fixture::build(fixture_cfg(90, 31));
    let engine = traced_engine(&fixture, 1024); // capacity > requests
    let report = run_closed_loop(&engine, &fixture, 6, &fixture.oracle());
    assert_eq!(report.ok, 90);
    assert_eq!(report.mismatches, 0);
    let snap = engine.stats();
    let (events, dropped) = engine.trace_snapshot().expect("tracing was on");
    engine.shutdown();
    assert_eq!(dropped, 0, "capacity above load: nothing may drop");
    assert_eq!(
        events.len(),
        90,
        "one lifecycle event per completed response, exactly"
    );
    let mut by_kind = [0u64; 3];
    for ev in &events {
        assert_decomposition(ev);
        // the engine path always crosses the admission queue, so the
        // queue stage is a real (positive) span on every ticket
        assert!(
            ev.stages.queue_s > 0.0,
            "engine-path ticket skipped the queue stage: {:?}",
            ev.stages
        );
        assert!(!ev.cache_hit, "repeat_frac=0 traffic cannot hit the cache");
        assert!(
            ev.stages.kernel_s > 0.0,
            "cache-miss ticket must carry a kernel bracket: {:?}",
            ev.stages
        );
        assert!(ev.total_s > 0.0);
        by_kind[ev.kind.index()] += 1;
    }
    // ring and stats agree class-by-class: the stage aggregates were fed
    // by exactly the events the ring saw
    assert_eq!(snap.stages.len(), 3);
    for st in &snap.stages {
        assert_eq!(
            st.n,
            by_kind[st.kind.index()],
            "stage aggregate count diverges from ring events for {:?}",
            st.kind
        );
    }
    assert_eq!(by_kind.iter().sum::<u64>(), snap.completed);
}

#[test]
fn stage_means_reconcile_with_end_to_end_latency_per_store_and_class() {
    // two stores so the per-store decompositions are exercised too
    let mut cfg = fixture_cfg(120, 32);
    cfg.stores = vec![
        StoreProfile {
            name: "s0".into(),
            weight: 2,
            ..base_profile()
        },
        StoreProfile {
            name: "s1".into(),
            dim: 1024,
            items: 32,
            ..base_profile()
        },
    ];
    let fixture = Fixture::build(cfg);
    let engine = traced_engine(&fixture, 4096);
    let report = run_closed_loop(&engine, &fixture, 6, &fixture.oracle());
    assert_eq!(report.ok, 120);
    assert_eq!(report.mismatches, 0);
    let snap = engine.stats();
    engine.shutdown();
    let check = |stages: &[nscog::serve::StageSummary], scope: &str| {
        assert_eq!(stages.len(), 3, "{scope}: one block per request class");
        let mut n_total = 0;
        for st in stages {
            if st.n == 0 {
                assert!(st.total.is_none(), "{scope}: empty class has no summary");
                continue;
            }
            n_total += st.n;
            let total = st.total.as_ref().expect("trafficked class has totals");
            let sum = st.stage_mean_sum_s();
            assert!(
                sum <= total.mean_s + 1e-9,
                "{scope}/{:?}: stage means over-attribute: {sum} > {}",
                st.kind,
                total.mean_s
            );
            assert!(sum > 0.0, "{scope}/{:?}: decomposition is empty", st.kind);
            // each stage's sample count matches the class's
            for part in [&st.queue, &st.batch, &st.kernel, &st.fill] {
                assert_eq!(
                    part.as_ref().map(|l| l.n),
                    Some(st.n as usize),
                    "{scope}/{:?}: stage sample count diverges",
                    st.kind
                );
            }
        }
        n_total
    };
    assert_eq!(check(&snap.stages, "engine"), 120);
    let per_store: u64 = snap
        .stores
        .iter()
        .map(|s| {
            let n = check(&s.stages, &s.name);
            assert_eq!(n, s.completed, "store {} stage counts vs completed", s.name);
            n
        })
        .sum();
    assert_eq!(per_store, 120);
}

#[test]
fn ring_overflow_drops_oldest_and_counts_exactly() {
    let fixture = Fixture::build(fixture_cfg(80, 33));
    let engine = traced_engine(&fixture, 16); // far below the load
    let report = run_closed_loop(&engine, &fixture, 4, &fixture.oracle());
    assert_eq!(report.ok, 80);
    let (events, dropped) = engine.trace_snapshot().expect("tracing was on");
    assert_eq!(engine.trace_capacity(), Some(16));
    engine.shutdown();
    assert_eq!(events.len(), 16, "wrapped ring retains exactly its capacity");
    assert_eq!(
        dropped as usize + events.len(),
        80,
        "drop counter accounts for every overwritten event"
    );
    // drop-oldest: what survives is the newest window, oldest-first
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert!(
        seqs.windows(2).all(|w| w[0] < w[1]),
        "snapshot not oldest-first: {seqs:?}"
    );
    assert_eq!(*seqs.last().unwrap(), 80, "newest event is the last recorded");
    assert_eq!(*seqs.first().unwrap(), 80 - 16 + 1, "oldest survivor is capacity back");
    for ev in &events {
        assert_decomposition(ev);
    }
}

#[test]
fn cache_hits_trace_without_a_kernel_bracket() {
    let mut cfg = fixture_cfg(100, 34);
    cfg.stores[0].repeat_frac = 0.5;
    cfg.stores[0].dim = 2048; // multi-chunk rows: the scans really prune
    let fixture = Fixture::build(cfg);
    let engine = traced_engine(&fixture, 4096);
    let report = run_closed_loop(&engine, &fixture, 6, &fixture.oracle());
    assert_eq!(report.ok, 100);
    assert_eq!(report.mismatches, 0);
    let snap = engine.stats();
    let (events, dropped) = engine.trace_snapshot().expect("tracing was on");
    engine.shutdown();
    assert_eq!(dropped, 0);
    assert_eq!(events.len(), 100);
    let hits: Vec<&TraceEvent> = events.iter().filter(|e| e.cache_hit).collect();
    assert!(
        !hits.is_empty(),
        "repeat_frac=0.5 over 100 requests must produce traced cache hits"
    );
    for ev in &hits {
        assert_decomposition(ev);
        assert_eq!(
            ev.stages.kernel_s, 0.0,
            "cache hits carry no kernel bracket; probe time lands in fill"
        );
        assert!(
            ev.kind != RequestKind::Factorize,
            "only recall-family responses are cacheable"
        );
    }
    let cache = snap.cache.expect("default engine cache enabled");
    assert_eq!(
        hits.len() as u64,
        cache.hits,
        "traced cache-hit events must match the cache's own hit counter"
    );
}

#[test]
fn gauges_are_layered_and_tracing_off_means_no_ring() {
    let fixture = Fixture::build(fixture_cfg(40, 35));
    // tracing OFF: the engine holds no ring and snapshots say so
    let cfg = EngineConfig {
        workers: 2,
        shards: 2,
        ..EngineConfig::default()
    };
    let engine = ServeEngine::start_registry(fixture.registry(&cfg), cfg).expect("spawn workers");
    let report = run_closed_loop(&engine, &fixture, 4, &fixture.oracle());
    assert_eq!(report.ok, 40);
    assert!(engine.trace_snapshot().is_none(), "untraced engine has no ring");
    assert_eq!(engine.trace_capacity(), None);
    let snap = engine.stats();
    engine.shutdown();
    // stage aggregation is always-on (it is O(1) P² state, not the ring)
    assert_eq!(snap.stages.iter().map(|s| s.n).sum::<u64>(), 40);
    // queue gauges are layered into every snapshot: drained after the
    // run, one lane per registered store
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(snap.lanes.len(), 1);
    assert_eq!(snap.lanes[0].len, 0);
    assert!(snap.lanes[0].weight >= 1);
    assert!(snap.lanes[0].quota >= 1);
}
