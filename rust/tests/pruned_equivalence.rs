//! Property tests for the cascaded sketch-prefilter + bound-pruned scan
//! engine: pruned `top_k` / `nearest` / batch scans must be bit-identical
//! to the exhaustive references — across k, sketch widths, adversarial
//! item distributions (duplicates, all-tie codebooks, near-duplicates),
//! dimensions that are not multiples of the bound chunk, and shard
//! boundaries — while measurably streaming fewer item words on the easy
//! (noisy member query) distribution.

use nscog::serve::ShardedCleanup;
use nscog::util::prop::forall_res;
use nscog::util::Rng;
use nscog::vsa::sketch::PRUNE_CHUNK_WORDS;
use nscog::vsa::{BinaryCodebook, BinaryHV, CleanupMemory, PruneStats, RealCodebook, RealHV};

/// Oracle: full sort by (score desc, index asc), truncate.
fn top_k_oracle<S: Copy + PartialOrd>(scores: &[S], k: usize) -> Vec<(usize, S)> {
    let mut all: Vec<(usize, S)> = scores.iter().copied().enumerate().collect();
    all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

fn flip_bits(hv: &BinaryHV, frac: f64, rng: &mut Rng) -> BinaryHV {
    let mut out = hv.clone();
    let n = (hv.dim() as f64 * frac) as usize;
    for i in rng.sample_indices(hv.dim(), n) {
        out.set(i, !out.get(i));
    }
    out
}

/// Random binary codebook in one of four item distributions:
/// 0 = independent random, 1 = duplicates (exact ties), 2 = all-tie
/// (every item identical), 3 = near-duplicates (adversarial for pruning).
fn gen_binary(rng: &mut Rng) -> (BinaryCodebook, Vec<BinaryHV>, usize) {
    // dims straddle sketch/no-sketch and non-multiple-of-chunk shapes
    let dims = [320usize, 512, 1024, 1088, 2048, 2624];
    let dim = dims[rng.below(dims.len())];
    let n = 1 + rng.below(28);
    let mode = rng.below(4);
    let items: Vec<BinaryHV> = match mode {
        0 => (0..n).map(|_| BinaryHV::random(rng, dim)).collect(),
        1 => {
            let base: Vec<BinaryHV> = (0..(n / 3 + 1))
                .map(|_| BinaryHV::random(rng, dim))
                .collect();
            (0..n).map(|_| base[rng.below(base.len())].clone()).collect()
        }
        2 => {
            let b = BinaryHV::random(rng, dim);
            vec![b; n]
        }
        _ => {
            let b = BinaryHV::random(rng, dim);
            (0..n).map(|_| flip_bits(&b, 0.03, rng)).collect()
        }
    };
    let cb = BinaryCodebook::from_items(dim, items);
    let queries: Vec<BinaryHV> = (0..4)
        .map(|q| {
            if q % 2 == 0 {
                BinaryHV::random(rng, dim)
            } else {
                flip_bits(cb.item(rng.below(n)), 0.2, rng)
            }
        })
        .collect();
    (cb, queries, mode)
}

#[test]
fn binary_pruned_scans_equal_exhaustive_everywhere() {
    forall_res(
        7001,
        60,
        gen_binary,
        |(cb, queries, _mode)| {
            let mut stats = PruneStats::default();
            // exercise default, explicit, and disabled sketch widths
            for sketch_bits in [None, Some(256usize), Some(0)] {
                let mut cb = cb.clone();
                if let Some(bits) = sketch_bits {
                    cb.rebuild_sketch(bits);
                }
                for query in queries {
                    let scores = cb.scores(query);
                    let nearest = cb.nearest(query);
                    if cb.nearest_pruned(query, &mut stats) != nearest {
                        return Err(format!("nearest diverged (sketch {sketch_bits:?})"));
                    }
                    for k in [1usize, 2, 5, cb.len(), cb.len() + 4] {
                        let want = top_k_oracle(&scores, k);
                        let got = cb.top_k_pruned(query, k, &mut stats);
                        if got != want {
                            return Err(format!(
                                "top_k diverged at k={k} (sketch {sketch_bits:?}): {got:?} != {want:?}"
                            ));
                        }
                        if cb.top_k(query, k) != want {
                            return Err(format!("exhaustive top_k oracle drift at k={k}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cascaded_pruned_scans_equal_exhaustive_everywhere() {
    // the coarse level is a looser upper bound than the sketch, never a
    // different order: enabling the cascade at any width — including
    // widths that do not divide the sketch or the dim, widths as wide as
    // the sketch (refused), and widths with no sidecar to hang off of —
    // must leave every pruned result bit-identical to exhaustive across
    // all four adversarial item distributions
    forall_res(7006, 50, gen_binary, |(cb, queries, _mode)| {
        let mut stats = PruneStats::default();
        for (sketch_bits, coarse_bits) in [
            (None, 128usize),     // default sidecar width per dim
            (Some(256usize), 64), // narrowest coarse level
            (Some(256), 128),
            (Some(512), 192), // coarse not a power-of-two fraction
            (Some(256), 256), // as wide as the sketch: must refuse
            (Some(0), 128),   // no sidecar: cascade cannot engage
        ] {
            let mut cb = cb.clone();
            if let Some(bits) = sketch_bits {
                cb.rebuild_sketch(bits);
            }
            let engaged = cb.enable_cascade(coarse_bits);
            // the codebook must forward the sketch's own engage predicate
            let want = cb.sketch().is_some_and(|sk| {
                coarse_bits / 64 > 0 && coarse_bits / 64 < sk.words_per_item()
            });
            if engaged != want {
                return Err(format!(
                    "cascade engage mismatch: got {engaged}, want {want} \
                     (sketch {sketch_bits:?}, coarse {coarse_bits})"
                ));
            }
            if engaged {
                let sk = cb.sketch().unwrap();
                if sk.coarse_bits() != (coarse_bits / 64) * 64 {
                    return Err(format!(
                        "coarse width not word-truncated: {} from {coarse_bits}",
                        sk.coarse_bits()
                    ));
                }
            }
            for query in queries {
                let scores = cb.scores(query);
                if cb.nearest_pruned(query, &mut stats) != cb.nearest(query) {
                    return Err(format!(
                        "nearest diverged (sketch {sketch_bits:?}, coarse {coarse_bits})"
                    ));
                }
                for k in [1usize, 2, 5, cb.len(), cb.len() + 4] {
                    let want = top_k_oracle(&scores, k);
                    let got = cb.top_k_pruned(query, k, &mut stats);
                    if got != want {
                        return Err(format!(
                            "top_k diverged at k={k} (sketch {sketch_bits:?}, \
                             coarse {coarse_bits}): {got:?} != {want:?}"
                        ));
                    }
                }
            }
        }
        // per-level ledger sanity: the three rejection classes are
        // disjoint item outcomes, and streaming never exceeds exhaustive
        if stats.coarse_rejected + stats.sketch_rejected + stats.early_terminated > stats.items {
            return Err(format!("rejection classes overlap: {stats:?}"));
        }
        if stats.words_streamed > stats.words_total {
            return Err(format!("streamed beyond exhaustive: {stats:?}"));
        }
        Ok(())
    });
}

#[test]
fn cascade_bulk_rejects_and_streams_fewer_words_on_near_duplicates() {
    // near-duplicate member queries (2% noise) are the regime the coarse
    // level targets: the best score sits close to dim, so the 128-bit
    // prefix bound rejects nearly the whole tail and the cascade streams
    // strictly fewer words than the single-level sketch at bit-identical
    // results. (At heavy noise the coarse bound dim - 2·prefix_ham is
    // vacuous — that regime is covered by the equivalence test above.)
    let mut rng = Rng::new(7007);
    let mut single = BinaryCodebook::random(&mut rng, 240, 8192);
    single.rebuild_sketch(512);
    let queries: Vec<BinaryHV> = (0..24)
        .map(|i| flip_bits(single.item((i * 11) % 240), 0.02, &mut rng))
        .collect();
    let (base_res, base_stats) = single.nearest_batch_pruned_with(&queries, 1);
    let mut casc = single.clone();
    assert!(casc.enable_cascade(128), "cascade must engage under a 512b sketch");
    let (casc_res, casc_stats) = casc.nearest_batch_pruned_with(&queries, 1);
    assert_eq!(base_res, casc_res, "cascade changed answers");
    for (q, query) in queries.iter().enumerate() {
        assert_eq!(casc_res[q], single.nearest(query), "q={q}");
    }
    assert!(
        casc_stats.coarse_rejected > 0,
        "near-duplicate queries must coarse-reject: {casc_stats:?}"
    );
    assert!(
        casc_stats.words_streamed < base_stats.words_streamed,
        "cascade must stream strictly fewer words: cascade {} vs single {}",
        casc_stats.words_streamed,
        base_stats.words_streamed
    );
    assert!(casc_stats.coarse_rejected <= casc_stats.items);
    assert!(
        casc_stats.coarse_rejected + casc_stats.sketch_rejected + casc_stats.early_terminated
            <= casc_stats.items,
        "rejection classes overlap: {casc_stats:?}"
    );
    assert!(casc_stats.coarse_reject_rate() > 0.5, "{casc_stats:?}");
    assert!(casc_stats.words_frac() < base_stats.words_frac());
}

fn gen_real(rng: &mut Rng) -> (RealCodebook, Vec<RealHV>) {
    let dims = [256usize, 512, 640, 1024, 1100, 1536];
    let dim = dims[rng.below(dims.len())];
    let n = 1 + rng.below(18);
    let mode = rng.below(3);
    let items: Vec<RealHV> = match mode {
        0 => (0..n).map(|_| RealHV::random_bipolar(rng, dim)).collect(),
        1 => {
            let base: Vec<RealHV> = (0..(n / 2 + 1))
                .map(|_| RealHV::random_bipolar(rng, dim))
                .collect();
            (0..n).map(|_| base[rng.below(base.len())].clone()).collect()
        }
        _ => (0..n).map(|_| RealHV::random_hrr(rng, dim)).collect(),
    };
    let cb = RealCodebook::from_items(dim, items);
    let queries: Vec<RealHV> = (0..3)
        .map(|q| {
            if q == 1 {
                cb.item(rng.below(n)).clone()
            } else {
                RealHV::random_bipolar(rng, dim)
            }
        })
        .collect();
    (cb, queries)
}

#[test]
fn real_pruned_scans_equal_exhaustive_everywhere() {
    forall_res(7002, 50, gen_real, |(cb, queries)| {
        let mut stats = PruneStats::default();
        for query in queries {
            let scores = cb.scores(query);
            if cb.nearest_pruned(query, &mut stats) != cb.nearest(query) {
                return Err("nearest diverged".into());
            }
            for k in [1usize, 3, cb.len(), cb.len() + 2] {
                let want = top_k_oracle(&scores, k);
                if cb.top_k_pruned(query, k, &mut stats) != want {
                    return Err(format!("top_k diverged at k={k}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn relu_pruned_pmf_equals_exhaustive_everywhere() {
    // The bound-ordered ReLU-pruned decode must reproduce `to_pmf`
    // exactly: the only rows it skips are ones whose upper bound proves a
    // non-positive score, which ReLU zeroes in the exhaustive path too.
    // Distributions include duplicates, HRR items, member, negated-member
    // (anti-correlated — where the zero threshold actually prunes), and
    // all-negative queries.
    forall_res(
        7005,
        40,
        |rng| {
            let dims = [256usize, 640, 1024, 1100, 1536];
            let dim = dims[rng.below(dims.len())];
            let n = 1 + rng.below(16);
            let mode = rng.below(3);
            let items: Vec<RealHV> = match mode {
                0 => (0..n).map(|_| RealHV::random_bipolar(rng, dim)).collect(),
                1 => {
                    let base: Vec<RealHV> = (0..(n / 2 + 1))
                        .map(|_| RealHV::random_bipolar(rng, dim))
                        .collect();
                    (0..n).map(|_| base[rng.below(base.len())].clone()).collect()
                }
                _ => (0..n).map(|_| RealHV::random_hrr(rng, dim)).collect(),
            };
            let cb = RealCodebook::from_items(dim, items);
            let mut queries = vec![
                RealHV::random_bipolar(rng, dim),
                cb.item(rng.below(n)).clone(),
            ];
            let mut neg = cb.item(rng.below(n)).clone();
            for v in neg.as_mut_slice().iter_mut() {
                *v = -*v;
            }
            queries.push(neg);
            let threads = 1 + rng.below(3);
            (cb, queries, threads)
        },
        |(cb, queries, threads)| {
            let (batch, stats) = cb.to_pmf_batch_pruned_with(queries, *threads);
            for (q, query) in queries.iter().enumerate() {
                if batch[q] != cb.to_pmf(query) {
                    return Err(format!("pmf diverged q={q} threads={threads}"));
                }
            }
            if stats.words_streamed > stats.words_total {
                return Err(format!("streamed beyond exhaustive: {stats:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_pruned_scans_preserve_tie_order_across_boundaries() {
    // duplicate items laid across shard boundaries force cross-shard
    // exact ties; the pruned sharded scan must keep the global
    // (score desc, index asc) order
    let mut rng = Rng::new(7003);
    for dim in [1024usize, 2048] {
        let a = BinaryHV::random(&mut rng, dim);
        let b = BinaryHV::random(&mut rng, dim);
        let items = vec![
            b.clone(),
            a.clone(),
            b.clone(),
            a.clone(),
            a.clone(),
            BinaryHV::random(&mut rng, dim),
            b.clone(),
        ];
        let cb = BinaryCodebook::from_items(dim, items);
        let cm = CleanupMemory::new(cb.clone());
        let queries = vec![a.clone(), b.clone(), flip_bits(&a, 0.1, &mut rng)];
        for shards in [2usize, 3, 7] {
            let sharded = ShardedCleanup::partition(&cb, shards);
            for threads in [1usize, 2] {
                let (recalls, _, _) = sharded.recall_batch_stats(&queries, threads);
                let (tops, _, _) = sharded.recall_topk_batch_stats(&queries, 4, threads);
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(
                        recalls[q],
                        cm.recall(query),
                        "dim={dim} shards={shards} threads={threads} q={q}"
                    );
                    assert_eq!(
                        tops[q],
                        cm.recall_topk(query, 4),
                        "dim={dim} shards={shards} threads={threads} q={q}"
                    );
                }
            }
        }
        // tie ranking sanity on the unsharded pruned path itself
        let mut stats = PruneStats::default();
        let top = cb.top_k_pruned(&a, 3, &mut stats);
        assert_eq!(
            top.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
            vec![1, 3, 4],
            "duplicate member ties must rank by ascending index (dim={dim})"
        );
    }
}

#[test]
fn easy_distribution_streams_measurably_fewer_words() {
    // the serve store shape (120x8192) with noisy member queries — the
    // acceptance distribution: pruned scans must stream < 100% of the
    // words an exhaustive scan reads, at bit-identical results
    let mut rng = Rng::new(7004);
    let cb = BinaryCodebook::random(&mut rng, 120, 8192);
    let queries: Vec<BinaryHV> = (0..24)
        .map(|i| flip_bits(cb.item((i * 7) % 120), 0.2, &mut rng))
        .collect();
    let (nearest, nstats) = cb.nearest_batch_pruned_with(&queries, 1);
    let (topk, kstats) = cb.top_k_batch_pruned_with(&queries, 5, 1);
    for (q, query) in queries.iter().enumerate() {
        assert_eq!(nearest[q], cb.nearest(query), "q={q}");
        assert_eq!(topk[q], cb.top_k(query, 5), "q={q}");
    }
    assert!(
        nstats.words_frac() < 1.0,
        "easy nearest must stream fewer words: {nstats:?}"
    );
    assert!(
        nstats.sketch_rejected + nstats.early_terminated > 0,
        "easy nearest must actually prune: {nstats:?}"
    );
    // top-5 thresholds are looser, but by construction the cascade can
    // never stream more than the exhaustive scan (sketch words are the
    // row prefix; full scans resume at the sketch boundary)
    assert!(
        kstats.words_frac() <= 1.0 + 1e-12,
        "top-5 streamed beyond exhaustive: {kstats:?}"
    );
    // chunk constant sanity: the incremental bound fires at fold granularity
    assert_eq!(PRUNE_CHUNK_WORDS * 64, 512);
}
