//! Verifies the resonator's steady-state sweeps are allocation-free: a
//! counting global allocator observes zero allocations across repeated
//! `sweep_with`/`factorize_with` calls once the scratch buffers exist.
//!
//! This file holds exactly one test so no concurrent libtest thread can
//! perturb the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use nscog::util::Rng;
use nscog::vsa::{BinaryCodebook, BinaryHV, RealCodebook, Resonator};

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn resonator_sweeps_allocate_nothing_in_steady_state() {
    // Same shape as the substrate's `factorizes_exact_composition` test,
    // which is known to converge well inside the iteration budget.
    let mut rng = Rng::new(1);
    let codebooks: Vec<RealCodebook> = (0..3)
        .map(|_| RealCodebook::random_bipolar(&mut rng, 8, 1024))
        .collect();
    let resonator = Resonator::new(codebooks, 60);
    let scene = resonator.compose(&[2, 5, 1]);

    let mut estimates = resonator.init_estimates();
    let mut scratch = resonator.make_scratch();
    // Warm-up: fills the per-factor score buffers to their final capacity.
    resonator.sweep_with(&scene, &mut estimates, &mut scratch);

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        resonator.sweep_with(&scene, &mut estimates, &mut scratch);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state sweeps must not touch the heap"
    );

    // init_estimates_into + the sweep loop inside factorize_with are also
    // allocation-free (including the bound-pruned per-factor index decode
    // over the scratch's reusable buffers); only the final
    // ResonatorResult (indices Vec) may allocate, bounded per call, not
    // per sweep.
    resonator.init_estimates_into(&mut estimates);
    // warm the decode buffers (qnorms/order) once
    let _ = resonator.factorize_with(&scene, &mut estimates, &mut scratch);
    resonator.init_estimates_into(&mut estimates);
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    resonator.init_estimates_into(&mut estimates);
    let result = resonator.factorize_with(&scene, &mut estimates, &mut scratch);
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(result.indices, vec![2, 5, 1]);
    assert!(
        after - before <= 2,
        "factorize_with should allocate only the result indices, saw {} allocations",
        after - before
    );

    // Steady-state batched codebook scans over reusable score buffers
    // (BinaryCodebook::scores_batch_into, single-threaded serve shape)
    // must not touch the heap once the buffers have warmed.
    let cb = BinaryCodebook::random(&mut rng, 24, 2048);
    let queries: Vec<BinaryHV> = (0..10).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
    let mut scores_out: Vec<Vec<i64>> = Vec::new();
    cb.scores_batch_into(&queries, 1, &mut scores_out); // warm-up
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..20 {
        cb.scores_batch_into(&queries, 1, &mut scores_out);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state batched scans must not touch the heap"
    );
    assert_eq!(scores_out[3], cb.scores(&queries[3]));

    // The SIMD dispatch layer itself must be allocation-free once the
    // process tier is cached (selection already happened during the
    // warm-ups above): repeated dispatched kernel calls over held buffers
    // stay off the heap.
    let x = queries[0].clone();
    let y = queries[1].clone();
    let xs: Vec<f32> = (0..513).map(|i| (i % 7) as f32 - 3.0).collect();
    let ys: Vec<f32> = (0..513).map(|i| (i % 5) as f32 - 2.0).collect();
    let mut axpy_out = vec![0.0f32; 513];
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    let mut sink = 0u32;
    let mut dsink = 0.0f64;
    for _ in 0..20 {
        sink = sink.wrapping_add(x.hamming_bulk(&y));
        sink = sink.wrapping_add(x.popcount());
        let mut acc = nscog::vsa::DotAcc::new();
        acc.accumulate(&xs, &ys);
        dsink += acc.value();
        nscog::vsa::kernels::axpy_f32(&mut axpy_out, 0.5, &xs);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "dispatched SIMD kernels must not heap-allocate (sink {sink} {dsink})"
    );

    // Serve-stats recording is on every worker's batch path and must be
    // O(1) memory: the P² streaming quantile state replaced the old
    // per-request latency vectors (and the PR 7 stage decompositions use
    // the same fixed-size estimators), so steady-state recording over
    // preallocated slices stays off the heap entirely.
    use nscog::serve::stats::{ServeStats, StoreWork};
    use nscog::serve::{KernelWork, RequestKind, StageSample, StoreId, TraceEvent, TraceRing};
    use std::time::Duration;
    let stats = ServeStats::new(&[("s0", 2), ("s1", 2)]);
    let latencies: Vec<(StoreId, RequestKind, Duration, StageSample)> = (0..8)
        .map(|i| {
            (
                StoreId(i % 2),
                [RequestKind::Recall, RequestKind::RecallTopK, RequestKind::Factorize][i % 3],
                Duration::from_micros(100 + 37 * i as u64),
                StageSample {
                    queue_s: 20e-6,
                    batch_s: 15e-6,
                    kernel_s: 40e-6,
                    fill_s: 5e-6,
                },
            )
        })
        .collect();
    let mut work = vec![(StoreId(0), StoreWork::default()), (StoreId(1), StoreWork::default())];
    for (si, (_, w)) in work.iter_mut().enumerate() {
        w.timings.push((si, 0.001));
        w.timings.push((1 - si, 0.002));
        w.measured[RequestKind::Recall.index()].merge(&KernelWork {
            calls: 1,
            elapsed_s: 40e-6,
            flops: 3 * 1024,
            bytes_read: 8 * 1024,
            bytes_written: 16,
        });
    }
    // warm-up: pushes every P² estimator past its 5-marker fill phase
    stats.record_batch(latencies.len(), &latencies, &work);
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..50 {
        stats.record_batch(latencies.len(), &latencies, &work);
        stats.record_rejected();
        stats.record_tenant_rejected(StoreId(1));
        stats.record_expired(StoreId(0), 1);
        stats.record_degraded(StoreId(1), 1);
        stats.record_internal(StoreId(0), 1);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state stats recording must not touch the heap"
    );

    // The trace ring preallocates its whole buffer at construction:
    // steady-state `record` (including drop-oldest overwrites once the
    // ring has wrapped) is a Copy-slot write and must stay off the heap.
    // (With tracing off the batcher holds no ring at all, so the traced
    // path's cost is a single `Option` branch — nothing to measure.)
    let ring = TraceRing::new(16);
    let ev = TraceEvent {
        seq: 0,
        store: StoreId(0),
        epoch: 0,
        kind: RequestKind::Recall,
        stages: StageSample {
            queue_s: 20e-6,
            batch_s: 15e-6,
            kernel_s: 40e-6,
            fill_s: 5e-6,
        },
        total_s: 90e-6,
        degraded: false,
        cache_hit: false,
    };
    ring.record(ev); // warm-up (and Mutex init effects, if any)
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..64 {
        ring.record(ev); // wraps at 16: exercises the overwrite path too
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state trace recording must not touch the heap"
    );
    let (events, dropped) = ring.snapshot();
    assert_eq!(events.len(), 16);
    assert_eq!(dropped, 65 - 16, "drop-oldest counter is exact");
}
