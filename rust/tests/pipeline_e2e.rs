//! Integration tests across the full L3 stack: workloads → profiler →
//! platform models → coordinator, and (when artifacts are built) the
//! PJRT runtime executing the AOT'd L2 graphs.

use nscog::coordinator::{ExecGraph, Scheduler};
use nscog::platform::Platform;
use nscog::profiler::taxonomy::PhaseKind;
use nscog::util::prop::forall;
use nscog::util::Rng;
use nscog::workloads::nvsa::{Nvsa, NvsaEngine};
use nscog::workloads::prae::Prae;
use nscog::workloads::{all_workloads, raven};

#[test]
fn takeaway1_symbolic_bottleneck_holds_on_all_gpu_like_platforms() {
    // NVSA/PrAE/VSAIT symbolic-dominance is platform-robust.
    for p in [Platform::rtx2080ti(), Platform::v100()] {
        for w in all_workloads() {
            if ["NVSA", "PrAE", "VSAIT"].contains(&w.name()) {
                let tb = p.trace_time(&w.trace(), None);
                assert!(
                    tb.symbolic_fraction() > 0.7,
                    "{} on {}: {}",
                    w.name(),
                    p.name,
                    tb.symbolic_fraction()
                );
            }
        }
    }
}

#[test]
fn takeaway4_memory_vs_compute_bound_split() {
    let gpu = Platform::rtx2080ti();
    for w in all_workloads() {
        let tr = w.trace();
        if w.name() == "VSAIT" {
            // VSAIT's symbolic phase includes one genuine GEMM (the random
            // hypervector projection); the *streaming* ops (key binds,
            // codebook lookups) are the memory-bound part — check them.
            let ridge = nscog::profiler::roofline::ridge_intensity(&gpu);
            for op in tr.select(Some(PhaseKind::Symbolic), None) {
                if op.name.contains("key_bind") || op.name.contains("inv_bind") {
                    assert!(op.intensity() < ridge, "{} not memory-bound", op.name);
                }
            }
            continue;
        }
        let sym = nscog::profiler::roofline::place(&tr, PhaseKind::Symbolic, &gpu);
        assert!(sym.memory_bound, "{} symbolic should be memory-bound", w.name());
    }
    // dense neural phases of the conv-frontend workloads may be launch-
    // limited at our scale; the kernel-level claim is in platform tests.
}

#[test]
fn takeaway2_ratio_stable_as_task_scales() {
    let gpu = Platform::rtx2080ti();
    let fractions: Vec<f64> = [2usize, 3]
        .iter()
        .map(|&grid| {
            let w = Nvsa { grid, ..Default::default() };
            gpu.trace_time(&nscog::workloads::Workload::trace(&w), None)
                .symbolic_fraction()
        })
        .collect();
    assert!(
        (fractions[0] - fractions[1]).abs() < 0.10,
        "symbolic share should be stable: {fractions:?}"
    );
}

#[test]
fn prop_rpm_engines_agree_on_easy_instances() {
    // With confident PMFs, NVSA (hypervector path) and PrAE (probability
    // path) should both be far above chance and mostly agree.
    let nvsa = NvsaEngine::new(Nvsa::default(), 1);
    let prae = Prae::default();
    let mut agree = 0;
    let mut total = 0;
    forall(
        777,
        25,
        |rng: &mut Rng| {
            let inst = raven::generate(rng, 3, 8);
            let pmfs = raven::panel_pmfs(&inst, 0.97);
            (inst, pmfs)
        },
        |(inst, pmfs)| {
            let a = nvsa.solve(inst, pmfs);
            let b = prae.solve(inst, pmfs);
            total += 1;
            if a.chosen == b.chosen {
                agree += 1;
            }
            true
        },
    );
    assert!(agree * 10 >= total * 7, "engines agree only {agree}/{total}");
}

#[test]
fn scheduler_runs_workload_graph_end_to_end() {
    let gpu = Platform::rtx2080ti();
    let w = Prae::default();
    let g = ExecGraph::from_trace(&nscog::workloads::Workload::trace(&w), &gpu);
    let n = g.nodes.len();
    let sched = Scheduler::new(g);
    let levels = sched.levels();
    // every node appears in exactly one level
    let covered: usize = levels.iter().map(|l| l.len()).sum();
    assert_eq!(covered, n);
    // deps always in earlier levels
    for (li, level) in levels.iter().enumerate() {
        for &i in level {
            for &d in &sched.graph.nodes[i].deps {
                let dl = levels.iter().position(|l| l.contains(&d)).unwrap();
                assert!(dl < li);
            }
        }
    }
}

#[test]
fn artifacts_execute_when_built() {
    if !nscog::config::artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = nscog::runtime::Runtime::new().expect("runtime");
    // every manifest entry compiles and runs with zero inputs
    let names: Vec<String> = rt.manifest.artifacts.keys().cloned().collect();
    assert!(names.len() >= 13);
    for name in names {
        let spec = rt.manifest.get(&name).unwrap().clone();
        let inputs: Vec<nscog::runtime::Tensor> = spec
            .inputs
            .iter()
            .map(|s| nscog::runtime::Tensor::zeros(s.shape.clone()))
            .collect();
        let outs = rt
            .run(&name, &inputs)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(outs.len(), spec.outputs.len(), "{name}");
        for (o, s) in outs.iter().zip(&spec.outputs) {
            assert_eq!(o.shape, s.shape, "{name}");
            assert!(o.data.iter().all(|x| x.is_finite()), "{name} non-finite");
        }
    }
}

#[test]
fn frontend_pmfs_drive_symbolic_engine() {
    if !nscog::config::artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = nscog::runtime::Runtime::new().unwrap();
    let dims = rt.manifest.dims;
    let mut rng = Rng::new(31);
    let panels = nscog::runtime::Tensor::new(
        vec![dims.panels, dims.img, dims.img, 1],
        (0..dims.panels * dims.img * dims.img)
            .map(|_| rng.normal() as f32)
            .collect(),
    );
    let outs = rt.run("nvsa_frontend", &[panels]).unwrap();
    // pipe frontend PMFs into the NVSA codebook transform and verify the
    // hypervectors decode back to valid distributions
    let engine = NvsaEngine::new(Nvsa::default(), 5);
    for (a, pmf) in outs.iter().enumerate() {
        for p in 0..dims.panels {
            let row: Vec<f64> = pmf.data[p * dims.attr_k..(p + 1) * dims.attr_k]
                .iter()
                .map(|&x| x as f64)
                .collect();
            let hv = engine.codebooks[a].weighted_bundle(&row);
            let back = engine.codebooks[a].to_pmf(&hv);
            let s: f64 = back.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "attr {a} panel {p}: sum {s}");
        }
    }
}
