//! Property tests for the CA-90 rematerialized (seeds-only) store
//! backing: every scan over a ca90 codebook must be bit-identical to the
//! same scan over its fully materialized ram twin — across sketch
//! widths, cascade on/off, duplicate seeds (exact ties), all-tie
//! codebooks, k ≥ items, thread counts, and the sharded serve path —
//! while holding ~dim/512 less resident row memory and never streaming
//! more words than an exhaustive scan reads.

use nscog::serve::ShardedCleanup;
use nscog::util::prop::forall_res;
use nscog::util::Rng;
use nscog::vsa::hypervector::{FOLD_BITS, FOLD_WORDS};
use nscog::vsa::{BinaryCodebook, BinaryHV, CleanupMemory, PruneStats};

fn flip_bits(hv: &BinaryHV, frac: f64, rng: &mut Rng) -> BinaryHV {
    let mut out = hv.clone();
    let n = (hv.dim() as f64 * frac) as usize;
    for i in rng.sample_indices(hv.dim(), n) {
        out.set(i, !out.get(i));
    }
    out
}

/// CA-90 codebook plus its ram twin, in one of three seed distributions:
/// 0 = independent random seeds, 1 = duplicate seeds (exact row ties —
/// CA-90 expansion is deterministic, so equal seeds mean equal rows),
/// 2 = all-tie (every seed identical). Sketch width and cascade state
/// are sampled too, including the no-sidecar and refused-cascade shapes.
fn gen_ca90(rng: &mut Rng) -> (BinaryCodebook, BinaryCodebook, Vec<BinaryHV>) {
    // ca90 dims must be positive multiples of the 512-bit fold; include
    // multi-fold dims so rematerialization really steps the CA
    let dims = [512usize, 1024, 1536, 2048, 2560];
    let dim = dims[rng.below(dims.len())];
    let n = 1 + rng.below(24);
    let mode = rng.below(3);
    let fresh = |rng: &mut Rng| -> Vec<u64> { (0..FOLD_WORDS).map(|_| rng.next_u64()).collect() };
    let seeds: Vec<Vec<u64>> = match mode {
        0 => (0..n).map(|_| fresh(rng)).collect(),
        1 => {
            let base: Vec<Vec<u64>> = (0..(n / 3 + 1)).map(|_| fresh(rng)).collect();
            (0..n).map(|_| base[rng.below(base.len())].clone()).collect()
        }
        _ => {
            let s = fresh(rng);
            vec![s; n]
        }
    };
    let sketch_bits = [None, Some(128usize), Some(256), Some(0)][rng.below(4)];
    let mut ca = BinaryCodebook::ca90_from_seeds(&seeds, dim, sketch_bits);
    if rng.below(2) == 1 {
        // 64 or 128-bit coarse level; silently refused when the sidecar
        // is absent or not strictly wider — both shapes must stay exact
        ca.enable_cascade(64 * (1 + rng.below(2)));
    }
    let ram = ca.materialized();
    let queries: Vec<BinaryHV> = (0..4)
        .map(|q| match q % 3 {
            0 => BinaryHV::random(rng, dim),
            1 => flip_bits(&ca.materialize_item(rng.below(n)), 0.2, rng),
            // near-duplicate member: the coarse bulk-reject regime
            _ => flip_bits(&ca.materialize_item(rng.below(n)), 0.02, rng),
        })
        .collect();
    (ca, ram, queries)
}

#[test]
fn remat_scans_equal_materialized_twin_everywhere() {
    forall_res(7101, 50, gen_ca90, |(ca, ram, queries)| {
        if !ca.is_ca90() || ram.is_ca90() {
            return Err("backing flags inverted".into());
        }
        // the twin must preserve sketch width and cascade state, else the
        // comparison below would exercise different prune paths
        match (ca.sketch(), ram.sketch()) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                if a.bits() != b.bits() || a.coarse_bits() != b.coarse_bits() {
                    return Err(format!(
                        "twin sidecar drift: {}x{} vs {}x{}",
                        a.bits(),
                        a.coarse_bits(),
                        b.bits(),
                        b.coarse_bits()
                    ));
                }
            }
            _ => return Err("twin sidecar presence drift".into()),
        }
        let mut ca_stats = PruneStats::default();
        let mut ram_stats = PruneStats::default();
        for query in queries {
            let want_nearest = ram.nearest(query);
            if ca.nearest(query) != want_nearest {
                return Err("exhaustive nearest diverged across backings".into());
            }
            if ca.nearest_pruned(query, &mut ca_stats) != want_nearest {
                return Err("remat nearest_pruned diverged".into());
            }
            if ram.nearest_pruned(query, &mut ram_stats) != want_nearest {
                return Err("ram nearest_pruned diverged".into());
            }
            for k in [1usize, 3, ca.len(), ca.len() + 2] {
                let want = ram.top_k(query, k);
                if ca.top_k(query, k) != want {
                    return Err(format!("exhaustive top_k diverged at k={k}"));
                }
                if ca.top_k_pruned(query, k, &mut ca_stats) != want {
                    return Err(format!("remat top_k_pruned diverged at k={k}"));
                }
            }
        }
        // regenerated words count as streamed words: the roofline
        // accounting invariant holds on both backings
        for (name, st) in [("ca90", &ca_stats), ("ram", &ram_stats)] {
            if st.words_streamed > st.words_total {
                return Err(format!("{name} streamed beyond exhaustive: {st:?}"));
            }
            if st.coarse_rejected + st.sketch_rejected + st.early_terminated > st.items {
                return Err(format!("{name} rejection classes overlap: {st:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn remat_batch_and_sharded_paths_match_the_twin() {
    forall_res(7102, 30, gen_ca90, |(ca, ram, queries)| {
        for threads in [1usize, 2] {
            let (n_ca, st_ca) = ca.nearest_batch_pruned_with(queries, threads);
            let (n_ram, _) = ram.nearest_batch_pruned_with(queries, threads);
            if n_ca != n_ram {
                return Err(format!("batch nearest diverged (threads={threads})"));
            }
            let (k_ca, _) = ca.top_k_batch_pruned_with(queries, 3, threads);
            let (k_ram, _) = ram.top_k_batch_pruned_with(queries, 3, threads);
            if k_ca != k_ram {
                return Err(format!("batch top_k diverged (threads={threads})"));
            }
            if st_ca.words_frac() > 1.0 + 1e-12 {
                return Err(format!("remat words_frac above roofline: {st_ca:?}"));
            }
        }
        // sharded serve path: seeds-only shards against the ram oracle
        let cm = CleanupMemory::new(ram.clone());
        for shards in [2usize, 3] {
            let sharded = ShardedCleanup::partition(ca, shards);
            if !sharded.is_ca90() {
                return Err("sharding dropped the seeds-only backing".into());
            }
            let (recalls, _, _) = sharded.recall_batch_stats(queries, 2);
            let (tops, _, _) = sharded.recall_topk_batch_stats(queries, 3, 2);
            for (q, query) in queries.iter().enumerate() {
                if recalls[q] != cm.recall(query) {
                    return Err(format!("sharded recall diverged (shards={shards} q={q})"));
                }
                if tops[q] != cm.recall_topk(query, 3) {
                    return Err(format!("sharded topk diverged (shards={shards} q={q})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn seeds_round_trip_and_memory_compression() {
    let mut rng = Rng::new(7103);
    for dim in [1024usize, 2048, 4096] {
        let seeds: Vec<Vec<u64>> = (0..60)
            .map(|_| (0..FOLD_WORDS).map(|_| rng.next_u64()).collect())
            .collect();
        let ca = BinaryCodebook::ca90_from_seeds(&seeds, dim, Some(256));
        let ram = ca.materialized();
        // seeds() must round-trip into an identical codebook
        let again = BinaryCodebook::ca90_from_seeds(&ca.seeds(), dim, Some(256));
        for i in 0..ca.len() {
            assert_eq!(ca.materialize_item(i), again.materialize_item(i), "i={i}");
            assert_eq!(ca.materialize_item(i), ram.item(i).clone(), "i={i}");
        }
        // resident row memory shrinks by exactly dim / FOLD_BITS; the
        // sidecar is byte-identical (it is always materialized)
        assert_eq!(
            ram.row_resident_bytes(),
            ca.row_resident_bytes() * (dim / FOLD_BITS),
            "dim={dim}"
        );
        assert_eq!(ram.sketch_resident_bytes(), ca.sketch_resident_bytes());
        assert_eq!(ca.backing_name(), "ca90");
        assert_eq!(ram.backing_name(), "ram");
        // item() must refuse on the seeds-only backing (loud failure
        // beats silently handing out a seed prefix as a row)
        let probe = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = ca.item(0);
        }));
        assert!(probe.is_err(), "item() must panic on ca90 backing");
    }
}
