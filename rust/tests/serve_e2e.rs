//! End-to-end tests for the serving engine: batched/sharded responses
//! must be bit-identical to the sequential oracle, shard merges must
//! match unsharded scans on both codebook families, and admission control
//! must reject (not queue) under overload and answer expired deadlines.

use nscog::serve::loadgen::{run_closed_loop, run_open_loop, Fixture, FixtureConfig, LoadMix};
use nscog::serve::queue::Priority;
use nscog::serve::{
    EngineConfig, ServeEngine, ServeError, ServeRequest, ShardedBinaryCodebook,
    ShardedRealCodebook,
};
use nscog::util::Rng;
use nscog::vsa::{BinaryCodebook, BinaryHV, RealCodebook, RealHV};
use std::time::Duration;

fn fixture_cfg(requests: usize, seed: u64) -> FixtureConfig {
    FixtureConfig {
        items: 48,
        dim: 1024,
        noise_frac: 0.2,
        topk_k: 4,
        fact_factors: 3,
        fact_items: 7,
        fact_dim: 512,
        fact_iters: 30,
        requests,
        mix: LoadMix {
            recall: 5,
            topk: 2,
            factorize: 1,
        },
        repeat_frac: 0.0,
        seed,
    }
}

#[test]
fn concurrent_batched_serving_is_bit_identical_to_oracle() {
    let fixture = Fixture::build(fixture_cfg(120, 11));
    let engine = ServeEngine::start(
        &fixture.codebook,
        Some(fixture.resonator.clone()),
        EngineConfig {
            workers: 3,
            shards: 5,
            scan_threads: 2,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    );
    let report = run_closed_loop(&engine, &fixture, 8, &fixture.oracle());
    assert_eq!(report.ok, 120, "rejected={} expired={}", report.rejected, report.expired);
    assert_eq!(
        report.mismatches, 0,
        "batched-sharded responses must be bit-identical to the sequential oracle"
    );
    let stats = engine.stats();
    assert_eq!(stats.completed, 120);
    assert!(stats.batches > 0);
    assert!(stats.mean_batch >= 1.0);
    // every shard participated in the scans
    assert!(stats.shards.iter().all(|s| s.scans > 0));
    engine.shutdown();
}

#[test]
fn open_loop_serving_matches_oracle_too() {
    let fixture = Fixture::build(fixture_cfg(60, 12));
    let engine = ServeEngine::start(
        &fixture.codebook,
        Some(fixture.resonator.clone()),
        EngineConfig {
            workers: 2,
            shards: 3,
            ..EngineConfig::default()
        },
    );
    let report = run_open_loop(&engine, &fixture, 3000.0, 4, &fixture.oracle());
    assert_eq!(report.ok + report.rejected + report.expired, 60);
    assert_eq!(report.mismatches, 0);
    assert_eq!(report.rejected, 0, "default queue must absorb this offered load");
    engine.shutdown();
}

#[test]
fn shard_merge_equals_unsharded_scan_on_both_codebooks() {
    let mut rng = Rng::new(21);
    // binary family
    let bcb = BinaryCodebook::random(&mut rng, 67, 2048);
    let bqueries: Vec<BinaryHV> = (0..23).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
    for shards in [2usize, 5, 11] {
        let sharded = ShardedBinaryCodebook::partition(&bcb, shards);
        let (nearest, _) = sharded.nearest_batch_timed(&bqueries, 3);
        let (topk, _) = sharded.top_k_batch_with(&bqueries, 6, 3);
        for (q, query) in bqueries.iter().enumerate() {
            assert_eq!(nearest[q], bcb.nearest(query), "binary shards={shards} q={q}");
            assert_eq!(topk[q], bcb.top_k(query, 6), "binary shards={shards} q={q}");
        }
    }
    // real family
    let rcb = RealCodebook::random_bipolar(&mut rng, 41, 512);
    let rqueries: Vec<RealHV> = (0..13).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
    for shards in [2usize, 4, 9] {
        let sharded = ShardedRealCodebook::partition(&rcb, shards);
        let nearest = sharded.nearest_batch_with(&rqueries, 3);
        let topk = sharded.top_k_batch_with(&rqueries, 5, 3);
        for (q, query) in rqueries.iter().enumerate() {
            assert_eq!(nearest[q], rcb.nearest(query), "real shards={shards} q={q}");
            assert_eq!(topk[q], rcb.top_k(query, 5), "real shards={shards} q={q}");
        }
    }
}

#[test]
fn cached_serving_is_bit_identical_and_never_crosses_k_or_class() {
    // repeated-query mix through an engine with the cache enabled: every
    // response (cached or computed) must equal the sequential oracle
    let fixture = Fixture::build(FixtureConfig {
        repeat_frac: 0.4,
        ..fixture_cfg(150, 13)
    });
    let engine = ServeEngine::start(
        &fixture.codebook,
        Some(fixture.resonator.clone()),
        EngineConfig {
            workers: 3,
            shards: 4,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    );
    let report = run_closed_loop(&engine, &fixture, 8, &fixture.oracle());
    assert_eq!(report.ok, 150);
    assert_eq!(
        report.mismatches, 0,
        "cached responses must be bit-identical to the oracle"
    );
    let snap = engine.stats();
    let cache = snap.cache.expect("cache enabled by default");
    assert!(cache.hits > 0, "repeat_frac=0.4 over 150 requests must hit");
    engine.shutdown();

    // class/k scoping: same query through recall, top-k(1), and two
    // different top-k widths — each answer matches its own oracle
    let mut rng = Rng::new(14);
    let cb = BinaryCodebook::random(&mut rng, 40, 1024);
    let cm = nscog::vsa::CleanupMemory::new(cb.clone());
    let engine = ServeEngine::start(&cb, None, EngineConfig::default());
    let q = BinaryHV::random(&mut rng, 1024);
    for _round in 0..2 {
        // second round is served from the cache; answers must not change
        let recall = engine
            .submit(ServeRequest::Recall { query: q.clone() })
            .unwrap();
        assert_eq!(
            recall,
            nscog::serve::ServeResponse::Recall {
                index: cm.recall(&q).0,
                cosine: cm.recall(&q).1,
            }
        );
        for k in [1usize, 3, 5] {
            let got = engine
                .submit(ServeRequest::RecallTopK {
                    query: q.clone(),
                    k,
                })
                .unwrap();
            assert_eq!(
                got,
                nscog::serve::ServeResponse::RecallTopK {
                    hits: cm.recall_topk(&q, k)
                },
                "k={k}"
            );
        }
    }
    let snap = engine.stats();
    let cache = snap.cache.unwrap();
    assert_eq!(cache.hits, 4, "round two should hit all four entries");
    assert_eq!(cache.entries, 4, "recall + three distinct k entries");
    engine.shutdown();
}

#[test]
fn overload_rejects_instead_of_queueing_unboundedly() {
    let mut rng = Rng::new(31);
    let cb = BinaryCodebook::random(&mut rng, 32, 1024);
    let resonator = nscog::vsa::Resonator::new(
        (0..3)
            .map(|_| RealCodebook::random_bipolar(&mut rng, 8, 1024))
            .collect(),
        60,
    );
    let engine = ServeEngine::start(
        &cb,
        Some(resonator.clone()),
        EngineConfig {
            workers: 1,
            shards: 2,
            max_batch: 1,
            max_delay: Duration::from_micros(0),
            queue_capacity: 4,
            ..EngineConfig::default()
        },
    );
    // occupy the single worker with slow factorizations
    let scene = resonator.compose(&[1, 2, 3]);
    let mut primers = Vec::new();
    for _ in 0..3 {
        primers.push(
            engine
                .submit_async(
                    ServeRequest::Factorize {
                        scene: scene.clone(),
                    },
                    Priority::Normal,
                    Duration::from_secs(30),
                )
                .expect("primer admitted"),
        );
    }
    std::thread::sleep(Duration::from_millis(50)); // worker now mid-batch
    // burst far beyond queue capacity: admission control must reject
    let mut admitted = 0;
    let mut rejected = 0;
    let mut pending = Vec::new();
    for _ in 0..64 {
        match engine.submit_async(
            ServeRequest::Recall {
                query: BinaryHV::random(&mut rng, 1024),
            },
            Priority::Normal,
            Duration::from_secs(30),
        ) {
            Ok(p) => {
                admitted += 1;
                pending.push(p);
            }
            Err(ServeError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(
        rejected > 0,
        "burst of 64 into a capacity-4 queue must trip backpressure (admitted {admitted})"
    );
    assert!(admitted <= 64 - rejected);
    // everything admitted still completes correctly
    for p in primers {
        p.wait().expect("primer completes");
    }
    for p in pending {
        p.wait().expect("admitted request completes");
    }
    assert!(engine.stats().rejected >= rejected as u64);
    engine.shutdown();
}

#[test]
fn expired_deadlines_are_answered_without_execution() {
    let mut rng = Rng::new(41);
    let cb = BinaryCodebook::random(&mut rng, 32, 1024);
    let engine = ServeEngine::start(&cb, None, EngineConfig::default());
    for _ in 0..4 {
        let got = engine.submit_with(
            ServeRequest::Recall {
                query: BinaryHV::random(&mut rng, 1024),
            },
            Priority::Normal,
            Duration::from_secs(0),
        );
        assert_eq!(got, Err(ServeError::DeadlineExceeded));
    }
    let stats = engine.stats();
    assert_eq!(stats.expired, 4);
    assert_eq!(stats.completed, 0);
    // live deadlines still served
    let q = BinaryHV::random(&mut rng, 1024);
    assert!(engine
        .submit_with(
            ServeRequest::Recall { query: q },
            Priority::High,
            Duration::from_secs(10),
        )
        .is_ok());
    engine.shutdown();
}
