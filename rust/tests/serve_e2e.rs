//! End-to-end tests for the serving engine: batched/sharded responses
//! must be bit-identical to their store's sequential oracle, shard merges
//! must match unsharded scans on both codebook families, interleaved
//! multi-store traffic must never cross-contaminate, and admission
//! control must reject (not queue) under overload, answer expired
//! deadlines, and refuse unknown store ids without panicking. The TCP
//! front-end rides the same contract: framed responses bit-exact over
//! real sockets, client deadlines propagated from the wire header, and
//! half-open peers reaped without touching live connections.

use nscog::serve::loadgen::{
    run_closed_loop, run_open_loop, Fixture, FixtureConfig, LoadMix, StoreBacking, StoreProfile,
};
use nscog::serve::queue::Priority;
use nscog::serve::{
    EngineConfig, FaultConfig, NetClient, NetConfig, NetServer, ServeEngine, ServeError,
    ServeRequest, ShardedBinaryCodebook, ShardedRealCodebook, StoreId, StoreRegistry, StoreSpec,
};
use nscog::util::Rng;
use nscog::vsa::{BinaryCodebook, BinaryHV, CleanupMemory, RealCodebook, RealHV};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn base_profile() -> StoreProfile {
    StoreProfile {
        name: "default".into(),
        items: 48,
        dim: 1024,
        topk_k: 4,
        fact_factors: 3,
        fact_items: 7,
        fact_dim: 512,
        fact_iters: 30,
        weight: 1,
        repeat_frac: 0.0,
        sketch_bits: None,
        quota: None,
        backing: StoreBacking::Ram,
        sketch_cascade: None,
    }
}

fn fixture_cfg(requests: usize, seed: u64) -> FixtureConfig {
    FixtureConfig {
        stores: vec![base_profile()],
        noise_frac: 0.2,
        requests,
        mix: LoadMix {
            recall: 5,
            topk: 2,
            factorize: 1,
        },
        seed,
    }
}

fn start(fixture: &Fixture, cfg: EngineConfig) -> ServeEngine {
    ServeEngine::start_registry(fixture.registry(&cfg), cfg).expect("spawn serve workers")
}

#[test]
fn concurrent_batched_serving_is_bit_identical_to_oracle() {
    let fixture = Fixture::build(fixture_cfg(120, 11));
    let engine = start(
        &fixture,
        EngineConfig {
            workers: 3,
            shards: 5,
            scan_threads: 2,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    );
    let report = run_closed_loop(&engine, &fixture, 8, &fixture.oracle());
    assert_eq!(report.ok, 120, "rejected={} expired={}", report.rejected, report.expired);
    assert_eq!(
        report.mismatches, 0,
        "batched-sharded responses must be bit-identical to the sequential oracle"
    );
    let stats = engine.stats();
    assert_eq!(stats.completed, 120);
    assert!(stats.batches > 0);
    assert!(stats.mean_batch >= 1.0);
    // every shard participated in the scans
    assert!(stats.shards.iter().all(|s| s.scans > 0));
    assert_eq!(stats.stores.len(), 1);
    assert_eq!(stats.stores[0].completed, 120);
    engine.shutdown();
}

#[test]
fn open_loop_serving_matches_oracle_too() {
    let fixture = Fixture::build(fixture_cfg(60, 12));
    let engine = start(
        &fixture,
        EngineConfig {
            workers: 2,
            shards: 3,
            ..EngineConfig::default()
        },
    );
    let report = run_open_loop(&engine, &fixture, 3000.0, 4, &fixture.oracle());
    assert_eq!(report.ok + report.rejected + report.expired, 60);
    assert_eq!(report.mismatches, 0);
    assert_eq!(report.rejected, 0, "default queue must absorb this offered load");
    engine.shutdown();
}

#[test]
fn interleaved_multi_store_requests_never_cross_contaminate() {
    // three stores with pairwise-different dimensions, item counts, and
    // top-k widths behind one queue; closed-loop clients interleave
    // traffic for all of them through shared micro-batches. Every
    // response must be bit-identical to its own store's oracle, and the
    // per-store scan telemetry must account for exactly that store's
    // items — the structural proof that no batched kernel call ever
    // mixed stores (a mixed call would either panic on dimensions or
    // corrupt the per-store item accounting checked below).
    let mut cfg = fixture_cfg(180, 21);
    cfg.stores = vec![
        StoreProfile {
            name: "small".into(),
            dim: 512,
            items: 24,
            topk_k: 2,
            weight: 3,
            ..base_profile()
        },
        StoreProfile {
            name: "mid".into(),
            dim: 1024,
            items: 48,
            topk_k: 4,
            weight: 2,
            ..base_profile()
        },
        StoreProfile {
            name: "large".into(),
            dim: 2048,
            items: 36,
            topk_k: 6,
            weight: 1,
            ..base_profile()
        },
    ];
    let fixture = Fixture::build(cfg);
    // cache off so the per-store kernel accounting below is exact: every
    // completed recall/top-k request is one kernel-scanned query
    let engine = start(
        &fixture,
        EngineConfig {
            workers: 3,
            shards: 3,
            max_batch: 16,
            max_delay: Duration::from_millis(1),
            cache_capacity: 0,
            ..EngineConfig::default()
        },
    );
    let report = run_closed_loop(&engine, &fixture, 9, &fixture.oracle());
    assert_eq!(report.ok, 180);
    assert_eq!(
        report.mismatches, 0,
        "interleaved multi-store responses must match each store's own oracle"
    );
    let snap = engine.stats();
    assert_eq!(snap.stores.len(), 3);
    // exact per-store attribution: a store's binary-scan prune items are
    // its item count x its kernel-scanned query count; its factorize
    // decode adds fact_factors x fact_items per factorization
    for (si, store) in snap.stores.iter().enumerate() {
        let profile = &fixture.stores[si].profile;
        let (mut scanned, mut factorized) = (0u64, 0u64);
        for r in &fixture.requests {
            if r.store != StoreId(si) {
                continue;
            }
            match r.kind() {
                nscog::serve::RequestKind::Recall | nscog::serve::RequestKind::RecallTopK => {
                    scanned += 1
                }
                nscog::serve::RequestKind::Factorize => factorized += 1,
            }
        }
        assert!(scanned > 0, "store {si} must receive scan traffic");
        let expected = scanned * profile.items as u64
            + factorized * (profile.fact_factors * profile.fact_items) as u64;
        assert_eq!(
            store.prune.items, expected,
            "store '{}' scan accounting off — a batch mixed stores?",
            store.name
        );
        assert_eq!(store.completed, scanned + factorized);
    }
    engine.shutdown();

    // malformed store ids are refused, not panicking — and the engine
    // keeps serving valid traffic afterwards
    let fixture = Fixture::build(fixture_cfg(8, 22));
    let engine = start(&fixture, EngineConfig::default());
    let got = engine.submit(ServeRequest::recall_on(StoreId(99), BinaryHV::zeros(1024)));
    assert_eq!(got, Err(ServeError::UnknownStore));
    let report = run_closed_loop(&engine, &fixture, 2, &fixture.oracle());
    assert_eq!(report.ok, 8);
    assert_eq!(report.mismatches, 0);
    engine.shutdown();
}

#[test]
fn shard_merge_equals_unsharded_scan_on_both_codebooks() {
    let mut rng = Rng::new(21);
    // binary family
    let bcb = BinaryCodebook::random(&mut rng, 67, 2048);
    let bqueries: Vec<BinaryHV> = (0..23).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
    for shards in [2usize, 5, 11] {
        let sharded = ShardedBinaryCodebook::partition(&bcb, shards);
        let (nearest, _) = sharded.nearest_batch_timed(&bqueries, 3);
        let (topk, _) = sharded.top_k_batch_with(&bqueries, 6, 3);
        for (q, query) in bqueries.iter().enumerate() {
            assert_eq!(nearest[q], bcb.nearest(query), "binary shards={shards} q={q}");
            assert_eq!(topk[q], bcb.top_k(query, 6), "binary shards={shards} q={q}");
        }
    }
    // real family
    let rcb = RealCodebook::random_bipolar(&mut rng, 41, 512);
    let rqueries: Vec<RealHV> = (0..13).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
    for shards in [2usize, 4, 9] {
        let sharded = ShardedRealCodebook::partition(&rcb, shards);
        let nearest = sharded.nearest_batch_with(&rqueries, 3);
        let topk = sharded.top_k_batch_with(&rqueries, 5, 3);
        for (q, query) in rqueries.iter().enumerate() {
            assert_eq!(nearest[q], rcb.nearest(query), "real shards={shards} q={q}");
            assert_eq!(topk[q], rcb.top_k(query, 5), "real shards={shards} q={q}");
        }
    }
}

#[test]
fn cached_serving_is_bit_identical_and_never_crosses_k_or_class() {
    // repeated-query mix through an engine with the cache enabled: every
    // response (cached or computed) must equal the sequential oracle
    let mut cfg = fixture_cfg(150, 13);
    cfg.stores[0].repeat_frac = 0.4;
    let fixture = Fixture::build(cfg);
    let engine = start(
        &fixture,
        EngineConfig {
            workers: 3,
            shards: 4,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    );
    let report = run_closed_loop(&engine, &fixture, 8, &fixture.oracle());
    assert_eq!(report.ok, 150);
    assert_eq!(
        report.mismatches, 0,
        "cached responses must be bit-identical to the oracle"
    );
    let snap = engine.stats();
    let cache = snap.cache.expect("cache enabled by default");
    assert!(cache.hits > 0, "repeat_frac=0.4 over 150 requests must hit");
    assert_eq!(
        snap.stores[0].cache.unwrap().hits,
        cache.hits,
        "single-store engine: per-store counters equal the aggregate"
    );
    engine.shutdown();

    // class/k scoping: same query through recall, top-k(1), and two
    // different top-k widths — each answer matches its own oracle
    let mut rng = Rng::new(14);
    let cb = BinaryCodebook::random(&mut rng, 40, 1024);
    let cm = CleanupMemory::new(cb.clone());
    let engine =
        ServeEngine::start(&cb, None, EngineConfig::default()).expect("spawn serve workers");
    let q = BinaryHV::random(&mut rng, 1024);
    for _round in 0..2 {
        // second round is served from the cache; answers must not change
        let recall = engine.submit(ServeRequest::recall(q.clone())).unwrap();
        assert_eq!(
            recall,
            nscog::serve::ServeResponse::Recall {
                index: cm.recall(&q).0,
                cosine: cm.recall(&q).1,
            }
        );
        for k in [1usize, 3, 5] {
            let got = engine
                .submit(ServeRequest::recall_topk(q.clone(), k))
                .unwrap();
            assert_eq!(
                got,
                nscog::serve::ServeResponse::RecallTopK {
                    hits: cm.recall_topk(&q, k)
                },
                "k={k}"
            );
        }
    }
    let snap = engine.stats();
    let cache = snap.cache.unwrap();
    assert_eq!(cache.hits, 4, "round two should hit all four entries");
    assert_eq!(cache.entries, 4, "recall + three distinct k entries");
    engine.shutdown();
}

#[test]
fn per_store_caches_keep_tenants_isolated() {
    // two stores with the SAME dimension and identical queries: cache
    // entries must never leak across them (the store id is part of every
    // cache key, and each store owns its own cache)
    let mut rng = Rng::new(61);
    let cb_a = BinaryCodebook::random(&mut rng, 32, 1024);
    let cb_b = BinaryCodebook::random(&mut rng, 32, 1024);
    let cm_a = CleanupMemory::new(cb_a.clone());
    let cm_b = CleanupMemory::new(cb_b.clone());
    let mut registry = StoreRegistry::new();
    let a = registry.register("a", &cb_a, None, StoreSpec::default());
    let b = registry.register("b", &cb_b, None, StoreSpec::default());
    let engine = ServeEngine::start_registry(registry, EngineConfig::default())
        .expect("spawn serve workers");
    let q = BinaryHV::random(&mut rng, 1024);
    for _round in 0..2 {
        // round 2 is served from each store's cache — still per-store
        let got_a = engine
            .submit(ServeRequest::recall_on(a, q.clone()))
            .unwrap();
        let got_b = engine
            .submit(ServeRequest::recall_on(b, q.clone()))
            .unwrap();
        let (ia, ca) = cm_a.recall(&q);
        let (ib, cbi) = cm_b.recall(&q);
        assert_eq!(got_a, nscog::serve::ServeResponse::Recall { index: ia, cosine: ca });
        assert_eq!(got_b, nscog::serve::ServeResponse::Recall { index: ib, cosine: cbi });
        // same query, different stores: the answers come from different
        // codebooks, so a cross-tenant cache hit would be observable
        assert!(
            got_a != got_b || (ia, ca) == (ib, cbi),
            "store B served store A's cached answer"
        );
    }
    let snap = engine.stats();
    assert_eq!(snap.stores[a.index()].cache.unwrap().hits, 1);
    assert_eq!(snap.stores[b.index()].cache.unwrap().hits, 1);
    engine.shutdown();
}

#[test]
fn overload_rejects_instead_of_queueing_unboundedly() {
    let mut rng = Rng::new(31);
    let cb = BinaryCodebook::random(&mut rng, 32, 1024);
    let resonator = nscog::vsa::Resonator::new(
        (0..3)
            .map(|_| RealCodebook::random_bipolar(&mut rng, 8, 1024))
            .collect(),
        60,
    );
    let engine = ServeEngine::start(
        &cb,
        Some(resonator.clone()),
        EngineConfig {
            workers: 1,
            shards: 2,
            max_batch: 1,
            max_delay: Duration::from_micros(0),
            queue_capacity: 4,
            ..EngineConfig::default()
        },
    )
    .expect("spawn serve workers");
    // occupy the single worker with slow factorizations
    let scene = resonator.compose(&[1, 2, 3]);
    let mut primers = Vec::new();
    for _ in 0..3 {
        primers.push(
            engine
                .submit_async(
                    ServeRequest::factorize(scene.clone()),
                    Priority::Normal,
                    Duration::from_secs(30),
                )
                .expect("primer admitted"),
        );
    }
    std::thread::sleep(Duration::from_millis(50)); // worker now mid-batch
    // burst far beyond queue capacity: admission control must reject
    let mut admitted = 0;
    let mut rejected = 0;
    let mut pending = Vec::new();
    for _ in 0..64 {
        match engine.submit_async(
            ServeRequest::recall(BinaryHV::random(&mut rng, 1024)),
            Priority::Normal,
            Duration::from_secs(30),
        ) {
            Ok(p) => {
                admitted += 1;
                pending.push(p);
            }
            Err(ServeError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(
        rejected > 0,
        "burst of 64 into a capacity-4 queue must trip backpressure (admitted {admitted})"
    );
    assert!(admitted <= 64 - rejected);
    // everything admitted still completes correctly
    for p in primers {
        p.wait().expect("primer completes");
    }
    for p in pending {
        p.wait().expect("admitted request completes");
    }
    assert!(engine.stats().rejected >= rejected as u64);
    engine.shutdown();
}

#[test]
fn expired_deadlines_are_answered_without_execution() {
    let mut rng = Rng::new(41);
    let cb = BinaryCodebook::random(&mut rng, 32, 1024);
    let engine =
        ServeEngine::start(&cb, None, EngineConfig::default()).expect("spawn serve workers");
    for _ in 0..4 {
        let got = engine.submit_with(
            ServeRequest::recall(BinaryHV::random(&mut rng, 1024)),
            Priority::Normal,
            Duration::from_secs(0),
        );
        assert_eq!(got, Err(ServeError::DeadlineExceeded));
    }
    let stats = engine.stats();
    assert_eq!(stats.expired, 4);
    assert_eq!(stats.completed, 0);
    // live deadlines still served
    let q = BinaryHV::random(&mut rng, 1024);
    assert!(engine
        .submit_with(
            ServeRequest::recall(q),
            Priority::High,
            Duration::from_secs(10),
        )
        .is_ok());
    engine.shutdown();
}

#[test]
fn single_tenant_flood_sheds_its_own_traffic_and_spares_the_others() {
    // one tenant fires far past its admission quota while two well-behaved
    // tenants run closed-loop through the same (single-worker, artificially
    // slowed) engine. The flood must be shed on the flooder's own lane —
    // the victims must never see TenantOverloaded and must complete ≥90%
    // of their traffic bit-exactly.
    let mut rng = Rng::new(71);
    let books: Vec<BinaryCodebook> = (0..3)
        .map(|_| BinaryCodebook::random(&mut rng, 32, 1024))
        .collect();
    let mut registry = StoreRegistry::new();
    let ids: Vec<StoreId> = books
        .iter()
        .enumerate()
        .map(|(i, cb)| {
            registry.register(
                if i == 0 { "flood" } else { ["", "v1", "v2"][i] },
                cb,
                None,
                StoreSpec {
                    quota: Some(if i == 0 { 2 } else { 8 }),
                    ..StoreSpec::default()
                },
            )
        })
        .collect();
    let engine = ServeEngine::start_registry(
        registry,
        EngineConfig {
            workers: 1,
            max_batch: 4,
            max_delay: Duration::from_micros(100),
            queue_capacity: 64,
            // slow every batch so the flood builds a real backlog
            faults: Some(FaultConfig {
                seed: 5,
                kernel_delay_prob: 1.0,
                kernel_delay: Duration::from_millis(2),
                ..FaultConfig::default()
            }),
            ..EngineConfig::default()
        },
    )
    .expect("spawn serve workers");
    let oracles: Vec<CleanupMemory> = books.iter().map(|cb| CleanupMemory::new(cb.clone())).collect();
    let queries: Vec<Vec<BinaryHV>> = (0..3)
        .map(|_| (0..30).map(|_| BinaryHV::random(&mut rng, 1024)).collect())
        .collect();
    let (eng, ids, oracles, queries) = (&engine, &ids, &oracles, &queries);
    let (flood_rejected, victim_ledgers) = std::thread::scope(|s| {
        let flood = s.spawn(move || {
            let mut rejected = 0usize;
            let mut pending = Vec::new();
            for q in queries[0].iter().cycle().take(80) {
                match eng.submit_async(
                    ServeRequest::recall_on(ids[0], q.clone()),
                    Priority::Normal,
                    Duration::from_secs(30),
                ) {
                    Ok(p) => pending.push(p),
                    Err(ServeError::TenantOverloaded) => rejected += 1,
                    Err(e) => panic!("flood hit a non-tenant admission error: {e}"),
                }
            }
            pending
                .into_iter()
                .for_each(|p| drop(p.wait().expect("admitted flood ticket completes")));
            rejected
        });
        let victims: Vec<_> = (1usize..3)
            .map(|si| {
                s.spawn(move || {
                    let (mut completed, mut shed) = (0usize, 0usize);
                    for q in &queries[si] {
                        match eng.submit(ServeRequest::recall_on(ids[si], q.clone())) {
                            Ok(resp) => {
                                let (index, cosine) = oracles[si].recall(q);
                                assert_eq!(
                                    resp,
                                    nscog::serve::ServeResponse::Recall { index, cosine },
                                    "victim {si} got a wrong answer during the flood"
                                );
                                completed += 1;
                            }
                            Err(ServeError::TenantOverloaded) => shed += 1,
                            Err(e) => panic!("victim {si} admission error: {e}"),
                        }
                    }
                    (completed, shed)
                })
            })
            .collect();
        let rejected = flood.join().expect("flooder thread panicked");
        let ledgers: Vec<(usize, usize)> = victims
            .into_iter()
            .map(|v| v.join().expect("victim thread panicked"))
            .collect();
        (rejected, ledgers)
    });
    assert!(
        flood_rejected > 0,
        "80 fire-and-forget submits into a quota-2 lane must trip tenant backpressure"
    );
    for (si, (completed, shed)) in victim_ledgers.iter().enumerate() {
        assert_eq!(*shed, 0, "victim {si} was shed on the flooder's behalf");
        assert!(
            completed * 10 >= queries[si + 1].len() * 9,
            "victim {si} completed only {completed}/{}",
            queries[si + 1].len()
        );
    }
    let snap = engine.stats();
    assert!(snap.stores[0].rejected_tenant >= flood_rejected as u64);
    assert_eq!(snap.stores[1].rejected_tenant, 0);
    assert_eq!(snap.stores[2].rejected_tenant, 0);
    assert_eq!(
        snap.rejected, 0,
        "quotas must shed the flood before the global capacity check trips"
    );
    engine.shutdown();
}

#[test]
fn deadline_storm_expires_per_store_without_touching_live_traffic() {
    // two stores; a storm of already-dead requests lands on each amid live
    // traffic. Every dead ticket is answered DeadlineExceeded and charged
    // to its own store; every live request completes bit-exactly.
    let mut rng = Rng::new(81);
    let cb_a = BinaryCodebook::random(&mut rng, 32, 1024);
    let cb_b = BinaryCodebook::random(&mut rng, 24, 512);
    let cm_a = CleanupMemory::new(cb_a.clone());
    let cm_b = CleanupMemory::new(cb_b.clone());
    let mut registry = StoreRegistry::new();
    let a = registry.register("a", &cb_a, None, StoreSpec::default());
    let b = registry.register("b", &cb_b, None, StoreSpec::default());
    let engine = ServeEngine::start_registry(registry, EngineConfig::default())
        .expect("spawn serve workers");
    let storm = [(a, 1024usize, 6usize), (b, 512, 4)];
    for &(id, dim, n) in &storm {
        for _ in 0..n {
            let got = engine.submit_with(
                ServeRequest::recall_on(id, BinaryHV::random(&mut rng, dim)),
                Priority::Normal,
                Duration::ZERO,
            );
            assert_eq!(got, Err(ServeError::DeadlineExceeded));
        }
        // live request on the same store, right behind the storm
        let q = BinaryHV::random(&mut rng, dim);
        let (index, cosine) = if id == a { cm_a.recall(&q) } else { cm_b.recall(&q) };
        assert_eq!(
            engine.submit(ServeRequest::recall_on(id, q)),
            Ok(nscog::serve::ServeResponse::Recall { index, cosine })
        );
    }
    let snap = engine.stats();
    assert_eq!(snap.expired, 10);
    assert_eq!(snap.stores[a.index()].expired_dropped, 6);
    assert_eq!(snap.stores[b.index()].expired_dropped, 4);
    assert_eq!(snap.completed, 2);
    engine.shutdown();
}

#[test]
fn wire_serving_is_bit_exact_and_the_client_deadline_rides_the_header() {
    // the whole mixed schedule (recall / top-k / factorize) through real
    // TCP framing: every response must equal its in-process oracle
    let fixture = Fixture::build(fixture_cfg(40, 51));
    let engine = Arc::new(start(
        &fixture,
        EngineConfig {
            workers: 2,
            shards: 3,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        },
    ));
    let server = NetServer::start(Arc::clone(&engine), "127.0.0.1:0", NetConfig::default())
        .expect("bind wire server");
    let mut client = NetClient::connect(server.addr()).expect("connect wire client");
    for req in &fixture.requests {
        assert_eq!(
            client.call(req).expect("wire call"),
            Ok(fixture.oracle_answer(req)),
            "wire response diverged from its oracle"
        );
    }
    server.shutdown();
    if let Ok(e) = Arc::try_unwrap(engine) {
        e.shutdown();
    }

    // deadline propagation: behind a single artificially slowed worker,
    // a request carrying a 1ms deadline in its wire header must expire
    // in queue, while the zero-deadline (= server default) request ahead
    // of it completes
    let mut rng = Rng::new(53);
    let cb = BinaryCodebook::random(&mut rng, 32, 1024);
    let engine = Arc::new(
        ServeEngine::start(
            &cb,
            None,
            EngineConfig {
                workers: 1,
                max_batch: 1,
                cache_capacity: 0,
                faults: Some(FaultConfig {
                    seed: 3,
                    kernel_delay_prob: 1.0,
                    kernel_delay: Duration::from_millis(25),
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
        )
        .expect("spawn serve workers"),
    );
    let server = NetServer::start(Arc::clone(&engine), "127.0.0.1:0", NetConfig::default())
        .expect("bind wire server");
    let mut client = NetClient::connect(server.addr()).expect("connect wire client");
    let q1 = BinaryHV::random(&mut rng, 1024);
    let q2 = BinaryHV::random(&mut rng, 1024);
    let first = client
        .send(&ServeRequest::recall(q1), Priority::Normal, 0)
        .unwrap();
    let doomed = client
        .send(&ServeRequest::recall(q2), Priority::Normal, 1_000)
        .unwrap();
    let mut got = std::collections::HashMap::new();
    for _ in 0..2 {
        let (id, outcome) = client.recv().expect("response frame");
        got.insert(id, outcome);
    }
    assert!(
        got[&first].is_ok(),
        "server-default deadline must serve: {:?}",
        got[&first]
    );
    assert_eq!(
        got[&doomed],
        Err(ServeError::DeadlineExceeded),
        "the 1ms wire deadline must expire behind the 25ms kernel"
    );
    server.shutdown();
    if let Ok(e) = Arc::try_unwrap(engine) {
        e.shutdown();
    }
}

#[test]
fn half_open_wire_connections_are_reaped_while_live_traffic_flows() {
    let fixture = Fixture::build(fixture_cfg(20, 52));
    let engine = Arc::new(start(&fixture, EngineConfig::default()));
    let server = NetServer::start(
        Arc::clone(&engine),
        "127.0.0.1:0",
        NetConfig {
            idle_timeout: Duration::from_millis(150),
            ..NetConfig::default()
        },
    )
    .expect("bind wire server");
    // two half-open carcasses: connect, say nothing, never FIN
    let carcass_a = TcpStream::connect(server.addr()).unwrap();
    let carcass_b = TcpStream::connect(server.addr()).unwrap();
    // live traffic keeps flowing on its own connection the whole time
    let mut client = NetClient::connect(server.addr()).expect("connect wire client");
    let req = &fixture.requests[0];
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.counters().halfopen_reaped < 2 && Instant::now() < deadline {
        assert_eq!(
            client.call(req).expect("live call"),
            Ok(fixture.oracle_answer(req)),
            "live connection must serve bit-exactly while carcasses are reaped"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(
        server.counters().halfopen_reaped,
        2,
        "both idle carcasses reaped within the idle deadline"
    );
    drop((carcass_a, carcass_b));
    // the reaps never touched the live connection
    let req = &fixture.requests[1];
    assert_eq!(
        client.call(req).expect("live call after reaps"),
        Ok(fixture.oracle_answer(req))
    );
    server.shutdown();
    if let Ok(e) = Arc::try_unwrap(engine) {
        e.shutdown();
    }
}

#[test]
fn contained_worker_panic_answers_internal_and_engine_recovers() {
    let mut rng = Rng::new(91);
    let cb = BinaryCodebook::random(&mut rng, 32, 1024);
    let cm = CleanupMemory::new(cb.clone());
    let engine = ServeEngine::start(
        &cb,
        None,
        EngineConfig {
            workers: 2,
            // fault plan armed but quiescent; the test flips it live
            faults: Some(FaultConfig {
                seed: 9,
                ..FaultConfig::default()
            }),
            ..EngineConfig::default()
        },
    )
    .expect("spawn serve workers");
    let faults = engine.faults().expect("engine carries its fault plan");
    faults.set_probs(0.0, 1.0, 0.0); // every batch panics
    for _ in 0..3 {
        let got = engine.submit(ServeRequest::recall(BinaryHV::random(&mut rng, 1024)));
        assert_eq!(
            got,
            Err(ServeError::Internal),
            "poisoned batch must be answered, not hung"
        );
    }
    faults.set_probs(0.0, 0.0, 0.0);
    // same engine, same workers: bit-exact service resumes
    let q = BinaryHV::random(&mut rng, 1024);
    let (index, cosine) = cm.recall(&q);
    assert_eq!(
        engine.submit(ServeRequest::recall(q)),
        Ok(nscog::serve::ServeResponse::Recall { index, cosine })
    );
    let snap = engine.stats();
    assert_eq!(snap.internal, 3);
    assert_eq!(snap.stores[0].internal, 3);
    assert_eq!(snap.completed, 1);
    engine.shutdown();
}
