//! Equivalence property tests for the word-sliced/batched/FFT kernel
//! engine: every optimized kernel must agree with its retained reference
//! implementation across randomized shapes, member counts, and thread
//! counts (replayable via the seeds reported by `util::prop` on failure).

use nscog::util::prop::{forall, forall_res};
use nscog::util::Rng;
use nscog::vsa::hypervector::{majority, majority_ref, DotAcc};
use nscog::vsa::kernels::{self, SimdTier};
use nscog::vsa::{ops, BinaryCodebook, BinaryHV, RealCodebook, RealHV};

#[test]
fn majority_equals_per_bit_reference() {
    // Word counts 1..=16 over dims 64..=1024; even counts exercise the
    // tie-break RNG, which must be drawn in identical order.
    forall(7001, 60, |r| {
        let d = 64 * (1 + r.below(16));
        let n = 1 + r.below(16);
        let vs: Vec<BinaryHV> = (0..n).map(|_| BinaryHV::random(r, d)).collect();
        (vs, r.next_u64())
    }, |(vs, tie_seed)| {
        let refs: Vec<&BinaryHV> = vs.iter().collect();
        majority(&refs, *tie_seed) == majority_ref(&refs, *tie_seed)
    });
}

#[test]
fn majority_all_equal_members_even_count_is_identity() {
    // With an even count of identical members every column is unanimous
    // (no ties), so the bundle is the member itself.
    let mut rng = Rng::new(7002);
    let v = BinaryHV::random(&mut rng, 2048);
    let refs: Vec<&BinaryHV> = (0..6).map(|_| &v).collect();
    assert_eq!(majority(&refs, 3), v);
    assert_eq!(majority_ref(&refs, 3), v);
}

#[test]
fn hamming_bulk_equals_per_word_reference() {
    forall(7009, 60, |r| {
        let d = 64 * (1 + r.below(40));
        (BinaryHV::random(r, d), BinaryHV::random(r, d))
    }, |(x, y)| x.hamming_bulk(y) == x.hamming(y) && x.dot_bulk(y) == x.dot(y));
}

#[test]
fn fft_conv_and_corr_match_direct_within_1e3() {
    // Power-of-two dims take the FFT path; compare against the O(D²)
    // reference elementwise.
    forall_res(7003, 16, |r| {
        let d = 32usize << r.below(6); // 32..1024
        let x: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        let y: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        (x, y)
    }, |(x, y)| {
        let xv = RealHV::from_vec(x.clone());
        let yv = RealHV::from_vec(y.clone());
        let checks = [
            ("conv", ops::circular_conv(&xv, &yv), ops::circular_conv_direct(&xv, &yv)),
            ("corr", ops::circular_corr(&xv, &yv), ops::circular_corr_direct(&xv, &yv)),
        ];
        for (label, fast, slow) in checks {
            for (i, (a, b)) in fast.as_slice().iter().zip(slow.as_slice()).enumerate() {
                if (a - b).abs() > 1e-3 {
                    return Err(format!("{label} d={} i={i}: fft {a} vs direct {b}", x.len()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn non_pow2_dims_use_direct_path_exactly() {
    let mut rng = Rng::new(7004);
    let x = RealHV::random_hrr(&mut rng, 300);
    let y = RealHV::random_hrr(&mut rng, 300);
    assert_eq!(ops::circular_conv(&x, &y), ops::circular_conv_direct(&x, &y));
    assert_eq!(ops::circular_corr(&x, &y), ops::circular_corr_direct(&x, &y));
}

#[test]
fn binary_nearest_batch_equals_per_query_across_threads() {
    forall_res(7005, 12, |r| {
        let d = 64 * (1 + r.below(8));
        let n_items = 1 + r.below(40);
        let n_queries = r.below(30);
        let cb = BinaryCodebook::random(r, n_items, d);
        let queries: Vec<BinaryHV> = (0..n_queries).map(|_| BinaryHV::random(r, d)).collect();
        let threads = 1 + r.below(6);
        (cb, queries, threads)
    }, |(cb, queries, threads)| {
        let batch = cb.nearest_batch_with(queries, *threads);
        let scores = cb.scores_batch_with(queries, *threads);
        for (q, query) in queries.iter().enumerate() {
            if batch[q] != cb.nearest(query) {
                return Err(format!("nearest mismatch q={q} threads={threads}"));
            }
            if scores[q] != cb.scores(query) {
                return Err(format!("scores mismatch q={q} threads={threads}"));
            }
        }
        Ok(())
    });
}

#[test]
fn real_nearest_batch_equals_per_query_across_threads() {
    forall_res(7006, 10, |r| {
        let d = 64 * (1 + r.below(8));
        let n_items = 1 + r.below(24);
        let n_queries = r.below(20);
        let cb = RealCodebook::random_bipolar(r, n_items, d);
        let queries: Vec<RealHV> = (0..n_queries).map(|_| RealHV::random_bipolar(r, d)).collect();
        let threads = 1 + r.below(4);
        (cb, queries, threads)
    }, |(cb, queries, threads)| {
        let batch = cb.nearest_batch_with(queries, *threads);
        for (q, query) in queries.iter().enumerate() {
            if batch[q] != cb.nearest(query) {
                return Err(format!("nearest mismatch q={q} threads={threads}"));
            }
        }
        Ok(())
    });
}

#[test]
fn simd_tiers_agree_on_hypervector_ops() {
    // Every supported dispatch tier must reproduce the scalar reference
    // on full hypervector operations — odd word counts (not multiples of
    // any vector width), duplicate rows (hamming 0 / all-tie scans), and
    // permute shifts that hit both the pure-rotation and funnel paths.
    forall_res(
        8001,
        40,
        |r| {
            let d = 64 * (1 + r.below(41)); // 64..2624 bits, odd word counts
            let x = BinaryHV::random(r, d);
            let y = if r.below(4) == 0 { x.clone() } else { BinaryHV::random(r, d) };
            let shift = r.range(-5000, 5000);
            (x, y, shift)
        },
        |(x, y, shift)| {
            let ham = x.hamming(y);
            for t in kernels::available_tiers() {
                if kernels::xor_hamming_tier(t, x.words(), y.words()) != ham {
                    return Err(format!("hamming diverged on {}", t.name()));
                }
                if kernels::popcount_words_tier(t, x.words()) != x.popcount() {
                    return Err(format!("popcount diverged on {}", t.name()));
                }
                let mut bound = x.words().to_vec();
                kernels::xor_into_tier(t, &mut bound, y.words());
                if bound != x.bind(y).words() {
                    return Err(format!("bind diverged on {}", t.name()));
                }
            }
            // dispatched permute (funnel shift) vs the per-bit naive oracle
            let fast = x.permute(*shift);
            let d = x.dim();
            let mut naive = BinaryHV::zeros(d);
            for i in 0..d {
                let dst = (((i as i64 + shift) % d as i64 + d as i64) % d as i64) as usize;
                naive.set(dst, x.get(i));
            }
            if fast != naive {
                return Err(format!("permute diverged at shift {shift}"));
            }
            Ok(())
        },
    );
}

#[test]
fn canonical_dot_is_tier_invariant_and_chunk_resumable() {
    // RealHV::dot (the sequential oracle every pruned scan must hand
    // back) equals a forced-scalar DotAcc accumulation bit-for-bit, for
    // dims that are not multiples of the 8-lane width and for arbitrary
    // resume points — on whatever tier this process dispatched.
    forall_res(
        8002,
        40,
        |r| {
            let d = 1 + r.below(600);
            let x: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
            let cut = r.below(d + 1);
            (x, y, cut)
        },
        |(x, y, cut)| {
            let xv = RealHV::from_vec(x.clone());
            let yv = RealHV::from_vec(y.clone());
            let want = xv.dot(&yv);
            let mut scalar_acc = DotAcc::new();
            scalar_acc.accumulate_tier(SimdTier::Scalar, x, y);
            if scalar_acc.value().to_bits() != want.to_bits() {
                return Err("forced-scalar dot != dispatched RealHV::dot".into());
            }
            let mut resumed = DotAcc::new();
            resumed.accumulate(&x[..*cut], &y[..*cut]);
            resumed.accumulate(&x[*cut..], &y[*cut..]);
            if resumed.value().to_bits() != want.to_bits() {
                return Err(format!("resumed dot diverged at cut {cut}"));
            }
            Ok(())
        },
    );
}

#[test]
fn active_tier_is_supported_and_named() {
    let t = kernels::active_tier();
    assert!(t.is_supported(), "dispatch resolved an unsupported tier");
    assert!(["scalar", "avx2", "neon"].contains(&t.name()));
    // the tier the bench JSONs report must be one the host can run
    assert!(kernels::available_tiers().contains(&t));
}

#[test]
fn nscog_threads_env_controls_default_worker_count() {
    // configured_threads is read per call: the env var set by CI (or a
    // shell) takes effect without process restarts.
    let base = nscog::util::parallel::configured_threads();
    assert!(base >= 1);
    // map_ranges must behave identically for any worker count.
    let cb = {
        let mut rng = Rng::new(7007);
        BinaryCodebook::random(&mut rng, 17, 512)
    };
    let queries: Vec<BinaryHV> = {
        let mut rng = Rng::new(7008);
        (0..9).map(|_| BinaryHV::random(&mut rng, 512)).collect()
    };
    let serial = cb.nearest_batch_with(&queries, 1);
    assert_eq!(cb.nearest_batch(&queries), serial);
    for threads in 2..=8 {
        assert_eq!(cb.nearest_batch_with(&queries, threads), serial);
    }
}
