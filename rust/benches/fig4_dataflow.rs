//! Regenerates paper Fig. 4: operator-graph dependency / critical-path
//! analysis of the seven workloads.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Fig. 4 — operation & dataflow analysis ==");
    figures::fig4().print();
    println!();
    bench("fig4/critical-path over all workloads", || {
        nscog::util::bench::black_box(figures::fig4());
    });
}
