//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): VSA substrate ops,
//! the accelerator simulator's word throughput, and PJRT execution.
//!
//! The L3 kernel-engine entries measure the optimized kernels against the
//! retained reference implementations in the same run (word-sliced vs
//! per-bit `majority`, FFT vs direct `circular_conv`, batched vs
//! per-query `nearest`, scratch-reusing vs allocating `factorize`) and
//! emit machine-readable results to `BENCH_hotpath.json` (path override:
//! `NSCOG_BENCH_JSON`) so CI can track the perf trajectory across PRs.
use nscog::accel::{isa::ControlMethod, AccelConfig};
use nscog::serve::ShardedBinaryCodebook;
use nscog::util::bench::{bench, black_box, sample};
use nscog::util::stats::Summary;
use nscog::util::Rng;
use nscog::vsa::hypervector::{majority, majority_ref};
use nscog::vsa::{ops, BinaryCodebook, BinaryHV, RealCodebook, RealHV, Resonator};
use nscog::vsa::PruneStats;
use nscog::workloads::suite::{CompiledSuite, SuiteKind};

/// One recorded measurement for the JSON trajectory file.
struct Entry {
    name: String,
    s: Summary,
}

fn record(entries: &mut Vec<Entry>, name: &str, f: impl FnMut()) -> Summary {
    let s = bench(name, f);
    entries.push(Entry {
        name: name.to_string(),
        s,
    });
    s
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Large-store verdict block (PR 10): the ≥200k-item scan modes, their
/// per-level prune tallies, and whether the ca90 remat scan matched the
/// ram scans bit-exactly — what ci.sh's large-store validator gates on.
struct LargeStore {
    items: usize,
    dim: usize,
    remat_equal: bool,
    single: PruneStats,
    cascade: PruneStats,
    remat: PruneStats,
}

fn write_json(
    entries: &[Entry],
    speedups: &[(String, f64, f64)],
    prune: &[(String, PruneStats)],
    large: &Option<LargeStore>,
) {
    let path = std::env::var("NSCOG_BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    // which SIMD dispatch tier produced these numbers: ci.sh reruns this
    // bench under NSCOG_SIMD=scalar and merges the two JSONs into
    // simd-vs-scalar speedup entries keyed on this field
    let mut out = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"simd\": \"{}\",\n  \"entries\": [\n",
        nscog::vsa::kernels::active_tier().name()
    );
    for (i, e) in entries.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"p50_s\": {:e}, \"p95_s\": {:e}, \"min_s\": {:e}, \"samples\": {}}}{}\n",
            json_escape(&e.name),
            e.s.p50,
            e.s.p95,
            e.s.min,
            e.s.n,
            if i + 1 < entries.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n  \"speedups\": [\n");
    for (i, (kernel, ref_p50, opt_p50)) in speedups.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"ref_p50_s\": {:e}, \"opt_p50_s\": {:e}, \"speedup\": {:.2}}}{}\n",
            json_escape(kernel),
            ref_p50,
            opt_p50,
            ref_p50 / opt_p50,
            if i + 1 < speedups.len() { "," } else { "" },
        ));
    }
    let prune_json = |st: &PruneStats| {
        format!(
            "{{\"items\": {}, \"coarse_rejected\": {}, \"sketch_rejected\": {}, \"early_terminated\": {}, \"words_streamed\": {}, \"words_total\": {}, \"coarse_reject_rate\": {:.4}, \"sketch_reject_rate\": {:.4}, \"words_frac\": {:.4}}}",
            st.items,
            st.coarse_rejected,
            st.sketch_rejected,
            st.early_terminated,
            st.words_streamed,
            st.words_total,
            st.coarse_reject_rate(),
            st.sketch_reject_rate(),
            st.words_frac()
        )
    };
    out.push_str("  ],\n  \"prune\": [\n");
    for (i, (name, st)) in prune.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"stats\": {}}}{}\n",
            json_escape(name),
            prune_json(st),
            if i + 1 < prune.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n");
    match large {
        Some(l) => out.push_str(&format!(
            "  \"large_store\": {{\"items\": {}, \"dim\": {}, \"remat_equal\": {}, \"single\": {}, \"cascade\": {}, \"remat\": {}}}\n",
            l.items,
            l.dim,
            l.remat_equal,
            prune_json(&l.single),
            prune_json(&l.cascade),
            prune_json(&l.remat)
        )),
        None => out.push_str("  \"large_store\": null\n"),
    }
    out.push_str("}\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let mut rng = Rng::new(42);
    let d = 8192;
    let mut entries: Vec<Entry> = Vec::new();
    let mut speedups: Vec<(String, f64, f64)> = Vec::new();
    let mut prune_stats: Vec<(String, PruneStats)> = Vec::new();
    println!(
        "simd dispatch tier: {} (NSCOG_SIMD overrides; ci.sh A/Bs scalar vs auto)",
        nscog::vsa::kernels::active_tier().name()
    );

    // --- L3 VSA substrate -------------------------------------------------
    let a = BinaryHV::random(&mut rng, d);
    let b = BinaryHV::random(&mut rng, d);
    let s = record(&mut entries, "vsa/binary_bind 8192b", || {
        black_box(a.bind(&b));
    });
    println!(
        "    → {:.2} GB/s effective",
        (3.0 * d as f64 / 8.0) / s.p50 / 1e9
    );
    let mut acc = a.clone();
    record(&mut entries, "vsa/binary_bind_assign 8192b (no alloc)", || {
        acc.bind_assign(black_box(&b));
    });

    // dispatched word kernels in isolation: same entry names under
    // NSCOG_SIMD=scalar and auto runs, so ci.sh can ratio them into the
    // simd-vs-scalar speedup table. Loop x16 so one sample is ~µs-scale.
    let s_ham = record(&mut entries, "vsa/hamming_bulk 8192b x16", || {
        for _ in 0..16 {
            black_box(black_box(&a).hamming_bulk(black_box(&b)));
        }
    });
    println!(
        "    → {:.2} GB/s hamming kernel",
        (16.0 * 2.0 * d as f64 / 8.0) / s_ham.p50 / 1e9
    );
    record(&mut entries, "vsa/dot_bulk 8192b x16", || {
        for _ in 0..16 {
            black_box(black_box(&a).dot_bulk(black_box(&b)));
        }
    });

    // majority bundling: per-bit reference vs word-sliced CSA kernel
    let members: Vec<BinaryHV> = (0..9).map(|_| BinaryHV::random(&mut rng, d)).collect();
    let refs: Vec<&BinaryHV> = members.iter().collect();
    let s_ref = record(&mut entries, "vsa/majority_ref 9x8192b (per-bit)", || {
        black_box(majority_ref(&refs, 7));
    });
    let s_opt = record(&mut entries, "vsa/majority 9x8192b (word-sliced)", || {
        black_box(majority(&refs, 7));
    });
    println!("    → word-sliced speedup {:.1}x", s_ref.p50 / s_opt.p50);
    speedups.push(("majority 9x8192b".into(), s_ref.p50, s_opt.p50));

    // codebook scan: single query, then 100 queries per-query vs batched
    let cb = BinaryCodebook::random(&mut rng, 120, d);
    let q = BinaryHV::random(&mut rng, d);
    let s = record(&mut entries, "vsa/nearest 120x8192b", || {
        black_box(cb.nearest(&q));
    });
    println!(
        "    → {:.2} GB/s codebook scan",
        (120.0 * d as f64 / 8.0) / s.p50 / 1e9
    );
    let queries: Vec<BinaryHV> = (0..100).map(|_| BinaryHV::random(&mut rng, d)).collect();
    let s_ref = record(&mut entries, "vsa/nearest x100 per-query loop", || {
        for query in &queries {
            black_box(cb.nearest(query));
        }
    });
    let s_opt = record(&mut entries, "vsa/nearest_batch 100q (blocked)", || {
        black_box(cb.nearest_batch_with(&queries, 1));
    });
    println!("    → query-blocked speedup {:.1}x", s_ref.p50 / s_opt.p50);
    speedups.push(("nearest 120x8192b x100q".into(), s_ref.p50, s_opt.p50));
    let threads = nscog::util::parallel::configured_threads();
    if threads > 1 {
        let s_par = record(
            &mut entries,
            &format!("vsa/nearest_batch 100q ({threads} threads)"),
            || {
                black_box(cb.nearest_batch_with(&queries, threads));
            },
        );
        println!("    → threaded speedup {:.1}x", s_ref.p50 / s_par.p50);
    }

    // sharded store: same scan split across 4 shards (the serving
    // engine's layout), merged back — measured against the per-query loop
    // like nearest_batch, plus the top-k variant
    let sharded = ShardedBinaryCodebook::partition(&cb, 4);
    let shard_threads = threads.max(4);
    let s_shard = record(
        &mut entries,
        &format!("serve/sharded_nearest 4sh 100q ({shard_threads} threads)"),
        || {
            black_box(sharded.nearest_batch_with(&queries, shard_threads));
        },
    );
    println!("    → sharded speedup {:.1}x vs per-query", s_ref.p50 / s_shard.p50);
    speedups.push((
        "sharded nearest 4sh 120x8192b x100q".into(),
        s_ref.p50,
        s_shard.p50,
    ));
    record(&mut entries, "serve/sharded_topk5 4sh 100q", || {
        black_box(sharded.top_k_batch_with(&queries, 5, shard_threads));
    });

    // multi-store serving layout: two registered stores with different
    // dimensions behind one registry, each batch routed to its own
    // store's sharded scan (what `batcher::execute` does per
    // (store, class) group). The entry name carries the store count and
    // the JSON's top-level "simd" field the dispatch tier, so
    // multi-store serve numbers stay attributable next to the ci.sh
    // simd_speedups A/B.
    {
        use nscog::serve::{StoreRegistry, StoreSpec};
        let cb_small = BinaryCodebook::random(&mut rng, 80, 4096);
        let mut registry = StoreRegistry::new();
        let spec = StoreSpec {
            shards: 4,
            cache_capacity: 0,
            ..StoreSpec::default()
        };
        registry.register("hot", &cb, None, spec);
        registry.register("cold", &cb_small, None, spec);
        let small_queries: Vec<BinaryHV> =
            (0..100).map(|_| BinaryHV::random(&mut rng, 4096)).collect();
        let s_multi = record(
            &mut entries,
            "serve/multistore 2st recall_batch 100q+100q",
            || {
                for (store, qs) in registry.store_views().iter().zip([&queries, &small_queries]) {
                    black_box(store.cleanup().recall_batch_stats(qs, shard_threads));
                }
            },
        );
        println!(
            "    → 2-store routed scan: {:.2} GB/s aggregate",
            ((cb.len() * d + cb_small.len() * 4096) as f64 / 8.0 * 100.0) / s_multi.p50 / 1e9
        );
    }

    // --- cascaded sketch-prefilter + bound-pruned scans ------------------
    // easy distribution: noisy member queries (the serve workload shape);
    // adversarial: near-duplicate items, where exact pruning is worst-case
    let noisy = |src: &BinaryHV, frac: f64, rng: &mut Rng| {
        let mut q = src.clone();
        let flips = (d as f64 * frac) as usize;
        for j in rng.sample_indices(d, flips) {
            q.set(j, !q.get(j));
        }
        q
    };
    let easy_qs: Vec<BinaryHV> = (0..64)
        .map(|i| noisy(cb.item((i * 7) % cb.len()), 0.2, &mut rng))
        .collect();
    let adv_base = BinaryHV::random(&mut rng, d);
    let adv_cb = BinaryCodebook::from_items(
        d,
        (0..120).map(|_| noisy(&adv_base, 0.03, &mut rng)).collect(),
    );
    let adv_qs: Vec<BinaryHV> = (0..64)
        .map(|i| noisy(adv_cb.item((i * 11) % adv_cb.len()), 0.02, &mut rng))
        .collect();
    for (tag, scan_cb, qs) in [("easy", &cb, &easy_qs), ("adversarial", &adv_cb, &adv_qs)] {
        let s_ref = record(
            &mut entries,
            &format!("vsa/nearest_batch 64q {tag} (exhaustive)"),
            || {
                black_box(scan_cb.nearest_batch_with(qs, 1));
            },
        );
        let s_opt = record(
            &mut entries,
            &format!("vsa/nearest_batch 64q {tag} (pruned)"),
            || {
                black_box(scan_cb.nearest_batch_pruned_with(qs, 1));
            },
        );
        println!("    → pruned nearest {tag} speedup {:.2}x", s_ref.p50 / s_opt.p50);
        speedups.push((
            format!("pruned nearest {tag} 120x8192b x64q"),
            s_ref.p50,
            s_opt.p50,
        ));
        let (_, st) = scan_cb.nearest_batch_pruned_with(qs, 1);
        println!(
            "    → {tag} nearest: {:.1}% words streamed, sketch reject {:.1}%",
            st.words_frac() * 100.0,
            st.sketch_reject_rate() * 100.0
        );
        prune_stats.push((format!("pruned nearest {tag} 120x8192b x64q"), st));

        let s_ref = record(
            &mut entries,
            &format!("vsa/top_k5 64q {tag} (exhaustive)"),
            || {
                for q in qs {
                    black_box(scan_cb.top_k(q, 5));
                }
            },
        );
        let s_opt = record(
            &mut entries,
            &format!("vsa/top_k5 64q {tag} (pruned)"),
            || {
                black_box(scan_cb.top_k_batch_pruned_with(qs, 5, 1));
            },
        );
        println!("    → pruned top-5 {tag} speedup {:.2}x", s_ref.p50 / s_opt.p50);
        speedups.push((
            format!("pruned topk5 {tag} 120x8192b x64q"),
            s_ref.p50,
            s_opt.p50,
        ));
        let (_, st) = scan_cb.top_k_batch_pruned_with(qs, 5, 1);
        prune_stats.push((format!("pruned topk5 {tag} 120x8192b x64q"), st));
    }

    // --- large-store scaling: cascade + ca90 remat at 200k items ----------
    // The memory-roofline attack (PR 10) at a shape where bytes streamed
    // dominates: 200k x 2048b = 51 MiB of rows. Three scan modes over
    // bit-identical rows — single-level sketch, two-level cascade
    // (128-bit coarse pass orders + bulk-rejects the tail), and the
    // ca90 seeds-only backing that rematerializes surviving rows inside
    // the scan loop. All three must return bit-identical answers; the
    // per-level prune tallies and the remat-equality verdict go into
    // the JSON's "large_store" block for the ci.sh gate. NSCOG_LARGE=0
    // skips the section on tiny hosts.
    let large: Option<LargeStore> = if std::env::var("NSCOG_LARGE").map_or(true, |v| v != "0") {
        use nscog::vsa::hypervector::FOLD_WORDS;
        let ln = 200_000usize;
        let ld = 2048usize;
        let mut lrng = Rng::new(0xCA90);
        let seeds: Vec<Vec<u64>> = (0..ln)
            .map(|_| (0..FOLD_WORDS).map(|_| lrng.next_u64()).collect())
            .collect();
        let mut ca90_cb = BinaryCodebook::ca90_from_seeds(&seeds, ld, Some(512));
        assert!(ca90_cb.enable_cascade(128), "cascade must engage at 512b sketch");
        let ram_single = {
            let items: Vec<BinaryHV> = (0..ln).map(|i| ca90_cb.materialize_item(i)).collect();
            BinaryCodebook::from_items_sketched(ld, items, Some(512))
        };
        let mut ram_cascade = ram_single.clone();
        assert!(ram_cascade.enable_cascade(128));
        println!(
            "large store {ln}x{ld}b: resident rows ram {} vs ca90 {} ({:.1}x smaller)",
            nscog::util::stats::fmt_bytes(ram_single.row_resident_bytes()),
            nscog::util::stats::fmt_bytes(ca90_cb.row_resident_bytes()),
            ram_single.row_resident_bytes() as f64 / ca90_cb.row_resident_bytes() as f64
        );
        // near-duplicate member queries (2% noise): the high-score
        // regime the cascade targets — the k-th score sits close to dim,
        // so the 128-bit coarse bound (dim - 2·prefix_ham) can reject
        // almost the whole tail. At heavy noise the coarse bound is
        // vacuous and pruning falls back to incremental row bounds.
        let lqs: Vec<BinaryHV> = (0..8)
            .map(|i| {
                let mut q = ca90_cb.materialize_item((i * 25_013) % ln);
                for j in lrng.sample_indices(ld, ld / 50) {
                    q.set(j, !q.get(j));
                }
                q
            })
            .collect();
        let s_exh = record(&mut entries, "vsa/nearest_batch 8q 200kx2048b (exhaustive)", || {
            black_box(ram_single.nearest_batch_with(&lqs, 1));
        });
        let s_single = record(
            &mut entries,
            "vsa/nearest_batch 8q 200kx2048b (single-level sketch)",
            || {
                black_box(ram_single.nearest_batch_pruned_with(&lqs, 1));
            },
        );
        let s_casc = record(
            &mut entries,
            "vsa/nearest_batch 8q 200kx2048b (cascade 128)",
            || {
                black_box(ram_cascade.nearest_batch_pruned_with(&lqs, 1));
            },
        );
        let s_remat = record(
            &mut entries,
            "vsa/nearest_batch 8q 200kx2048b ca90 (cascade 128)",
            || {
                black_box(ca90_cb.nearest_batch_pruned_with(&lqs, 1));
            },
        );
        println!(
            "    → cascade speedup {:.2}x, remat {:.2}x vs exhaustive \
             (single-level {:.2}x)",
            s_exh.p50 / s_casc.p50,
            s_exh.p50 / s_remat.p50,
            s_exh.p50 / s_single.p50
        );
        speedups.push(("large cascade nearest 200kx2048b x8q".into(), s_exh.p50, s_casc.p50));
        speedups.push(("large remat nearest 200kx2048b x8q".into(), s_exh.p50, s_remat.p50));
        // exactness across all modes, plus the per-level prune ledgers
        let exhaustive = ram_single.nearest_batch_with(&lqs, 1);
        let (r_single, st_single) = ram_single.nearest_batch_pruned_with(&lqs, 1);
        let (r_casc, st_casc) = ram_cascade.nearest_batch_pruned_with(&lqs, 1);
        let (r_remat, st_remat) = ca90_cb.nearest_batch_pruned_with(&lqs, 1);
        let remat_equal = exhaustive == r_single && r_single == r_casc && r_casc == r_remat;
        assert!(remat_equal, "large-store scan modes diverged from exhaustive");
        println!(
            "    → words streamed: single-level {:.1}%, cascade {:.1}% \
             (coarse reject {:.1}%), ca90 remat {:.1}%",
            st_single.words_frac() * 100.0,
            st_casc.words_frac() * 100.0,
            st_casc.coarse_reject_rate() * 100.0,
            st_remat.words_frac() * 100.0
        );
        prune_stats.push(("large nearest 200kx2048b x8q (single-level)".into(), st_single));
        prune_stats.push(("large nearest 200kx2048b x8q (cascade128)".into(), st_casc));
        prune_stats.push(("large nearest 200kx2048b x8q ca90 (cascade128)".into(), st_remat));
        Some(LargeStore {
            items: ln,
            dim: ld,
            remat_equal,
            single: st_single,
            cascade: st_casc,
            remat: st_remat,
        })
    } else {
        println!("large-store section skipped (NSCOG_LARGE=0)");
        None
    };

    // HRR binding: direct O(D²) vs FFT O(D log D) at D=1024
    let ra = RealHV::random_bipolar(&mut rng, 1024);
    let rb = RealHV::random_bipolar(&mut rng, 1024);
    let s_ref = record(&mut entries, "vsa/circular_conv_direct 1024 f32", || {
        black_box(ops::circular_conv_direct(&ra, &rb));
    });
    let s_opt = record(&mut entries, "vsa/circular_conv 1024 f32 (fft)", || {
        black_box(ops::circular_conv(&ra, &rb));
    });
    println!("    → fft speedup {:.1}x", s_ref.p50 / s_opt.p50);
    speedups.push(("circular_conv 1024".into(), s_ref.p50, s_opt.p50));

    // resonator: full factorize, then steady-state with reused buffers
    let res = Resonator::new(
        (0..3)
            .map(|_| RealCodebook::random_bipolar(&mut rng, 10, 1024))
            .collect(),
        60,
    );
    let scene = res.compose(&[1, 2, 3]);
    let s_alloc = record(&mut entries, "vsa/resonator_factorize 3x10x1024", || {
        black_box(res.factorize(&scene));
    });
    let mut scratch = res.make_scratch();
    let mut estimates = res.init_estimates();
    let s_reuse = record(
        &mut entries,
        "vsa/resonator_factorize_with (reused bufs)",
        || {
            res.init_estimates_into(&mut estimates);
            black_box(res.factorize_with(&scene, &mut estimates, &mut scratch));
        },
    );
    println!(
        "    → buffer-reuse speedup {:.2}x",
        s_alloc.p50 / s_reuse.p50
    );
    speedups.push((
        "resonator_factorize 3x10x1024".into(),
        s_alloc.p50,
        s_reuse.p50,
    ));

    // --- accel simulator ---------------------------------------------------
    let mut suite = CompiledSuite::build(SuiteKind::React, AccelConfig::acc4(), 7);
    let words: usize = suite.programs.iter().map(|p| p.len()).sum();
    let times = sample(
        || {
            black_box(suite.run(ControlMethod::Mopc));
        },
        0.3,
        1.0,
    );
    let t = Summary::of(&times);
    entries.push(Entry {
        name: "accel/simulate REACT Acc4".into(),
        s: t,
    });
    println!(
        "accel/simulate REACT Acc4: {} words in {} → {:.2} M words/s",
        words,
        nscog::util::stats::fmt_time(t.p50),
        words as f64 / t.p50 / 1e6
    );

    // --- PJRT runtime (if artifacts built) ---------------------------------
    if let Ok(mut rt) = nscog::runtime::Runtime::new() {
        let dims = rt.manifest.dims;
        let mut r2 = Rng::new(9);
        let panels = nscog::runtime::Tensor::new(
            vec![dims.panels, dims.img, dims.img, 1],
            (0..dims.panels * dims.img * dims.img)
                .map(|_| r2.normal() as f32)
                .collect(),
        );
        rt.load("nvsa_frontend").unwrap();
        record(&mut entries, "runtime/nvsa_frontend PJRT execute", || {
            black_box(rt.run("nvsa_frontend", std::slice::from_ref(&panels)).unwrap());
        });
    } else {
        println!("runtime/: artifacts not built, skipping PJRT bench");
    }

    write_json(&entries, &speedups, &prune_stats, &large);
}
