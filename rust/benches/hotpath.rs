//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf): VSA substrate ops,
//! the accelerator simulator's word throughput, and PJRT execution.
use nscog::accel::{isa::ControlMethod, AccelConfig};
use nscog::util::bench::{bench, black_box, sample};
use nscog::util::Rng;
use nscog::vsa::{ops, BinaryCodebook, BinaryHV, RealCodebook, RealHV, Resonator};
use nscog::workloads::suite::{CompiledSuite, SuiteKind};

fn main() {
    let mut rng = Rng::new(42);
    let d = 8192;

    // --- L3 VSA substrate -------------------------------------------------
    let a = BinaryHV::random(&mut rng, d);
    let b = BinaryHV::random(&mut rng, d);
    let s = bench("vsa/binary_bind 8192b", || {
        black_box(a.bind(&b));
    });
    println!(
        "    → {:.2} GB/s effective",
        (3.0 * d as f64 / 8.0) / s.p50 / 1e9
    );
    let mut acc = a.clone();
    bench("vsa/binary_bind_assign 8192b (no alloc)", || {
        acc.bind_assign(black_box(&b));
    });
    let cb = BinaryCodebook::random(&mut rng, 120, d);
    let q = BinaryHV::random(&mut rng, d);
    let s = bench("vsa/nearest 120x8192b", || {
        black_box(cb.nearest(&q));
    });
    println!(
        "    → {:.2} GB/s codebook scan",
        (120.0 * d as f64 / 8.0) / s.p50 / 1e9
    );
    let ra = RealHV::random_bipolar(&mut rng, 1024);
    let rb = RealHV::random_bipolar(&mut rng, 1024);
    bench("vsa/circular_conv 1024 f32", || {
        black_box(ops::circular_conv(&ra, &rb));
    });
    let res = Resonator::new(
        (0..3)
            .map(|_| RealCodebook::random_bipolar(&mut rng, 10, 1024))
            .collect(),
        60,
    );
    let scene = res.compose(&[1, 2, 3]);
    bench("vsa/resonator_factorize 3x10x1024", || {
        black_box(res.factorize(&scene));
    });

    // --- accel simulator ---------------------------------------------------
    let mut suite = CompiledSuite::build(SuiteKind::React, AccelConfig::acc4(), 7);
    let words: usize = suite.programs.iter().map(|p| p.len()).sum();
    let times = sample(
        || {
            black_box(suite.run(ControlMethod::Mopc));
        },
        0.3,
        1.0,
    );
    let t = nscog::util::stats::Summary::of(&times);
    println!(
        "accel/simulate REACT Acc4: {} words in {} → {:.2} M words/s",
        words,
        nscog::util::stats::fmt_time(t.p50),
        words as f64 / t.p50 / 1e6
    );

    // --- PJRT runtime (if artifacts built) ---------------------------------
    if let Ok(mut rt) = nscog::runtime::Runtime::new() {
        let dims = rt.manifest.dims;
        let mut r2 = Rng::new(9);
        let panels = nscog::runtime::Tensor::new(
            vec![dims.panels, dims.img, dims.img, 1],
            (0..dims.panels * dims.img * dims.img)
                .map(|_| r2.normal() as f32)
                .collect(),
        );
        rt.load("nvsa_frontend").unwrap();
        bench("runtime/nvsa_frontend PJRT execute", || {
            black_box(rt.run("nvsa_frontend", std::slice::from_ref(&panels)).unwrap());
        });
    } else {
        println!("runtime/: artifacts not built, skipping PJRT bench");
    }
}
