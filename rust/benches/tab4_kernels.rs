//! Regenerates paper Tab. IV: simulated hardware counters of
//! representative neural vs symbolic kernels.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Tab. IV — kernel compute/memory/communication counters ==");
    figures::tab4().print();
    println!();
    bench("tab4/counter simulation", || {
        nscog::util::bench::black_box(figures::tab4());
    });
}
