//! Regenerates paper Fig. 11 (a/b): Acc2/4/8 latency+energy scaling and
//! the accelerator-vs-GPU comparison.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Fig. 11a — Acc2/Acc4/Acc8 across MULT/TREE/FACT/REACT ==");
    figures::fig11a().print();
    println!("\n== Fig. 11b — Acc vs V100 GPU ==");
    figures::fig11b().print();
    println!();
    bench("fig11/simulate FACT on Acc4 (MOPC)", || {
        use nscog::accel::{isa::ControlMethod, AccelConfig};
        use nscog::workloads::suite::{CompiledSuite, SuiteKind};
        let mut s = CompiledSuite::build(SuiteKind::Fact, AccelConfig::acc4(), 17);
        nscog::util::bench::black_box(s.run(ControlMethod::Mopc));
    });
}
