//! Regenerates paper Fig. 9: SOPC vs MOPC runtime & power on the
//! resonator-network workload across factor counts.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Fig. 9 — accelerator control methods (SOPC vs MOPC) ==");
    figures::fig9().print();
    println!();
    bench("fig9/resonator 3-factor both controls", || {
        nscog::util::bench::black_box(figures::fig9_point(3));
    });
}
