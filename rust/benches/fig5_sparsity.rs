//! Regenerates paper Fig. 5: sparsity of NVSA symbolic modules measured
//! on live data flowing through the Rust engine.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Fig. 5 — NVSA symbolic-module sparsity ==");
    figures::fig5().print();
    println!();
    bench("fig5/nvsa solve + sparsity measurement", || {
        nscog::util::bench::black_box(figures::fig5());
    });
}
