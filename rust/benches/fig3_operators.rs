//! Regenerates paper Fig. 3 (a/b/c): operator categories, memory usage,
//! and roofline placement.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Fig. 3a — compute operator runtime breakdown ==");
    figures::fig3a().print();
    println!("\n== Fig. 3b — memory usage ==");
    figures::fig3b().print();
    println!("\n== Fig. 3c — roofline analysis (RTX 2080 Ti) ==");
    figures::fig3c().print();
    println!();
    bench("fig3/operator+roofline analysis", || {
        nscog::util::bench::black_box(figures::fig3c());
    });
}
