//! Regenerates paper Fig. 2 (a/b/c): neural vs symbolic runtime, edge
//! platform scaling, and NVSA task-size scaling.
use nscog::figures;
use nscog::util::bench::bench;

fn main() {
    println!("== Fig. 2a — neural vs symbolic runtime breakdown ==");
    figures::fig2a().print();
    println!("\n== Fig. 2b — NVSA/NLM across TX2 / Xavier NX / RTX ==");
    figures::fig2b().print();
    println!("\n== Fig. 2c — NVSA latency vs RPM task size ==");
    figures::fig2c().print();
    println!();
    bench("fig2/trace+model all workloads", || {
        nscog::util::bench::black_box(figures::fig2a());
    });
}
